"""Serving warm-up from the tuning cache.

A replica's first request otherwise pays jit tracing + compilation for
every kernel shape it serves — at trigger latency budgets (µs) that is
catastrophic. The tuning cache already knows exactly which
(kernel, shape, dtype, backend) problems the deployment emits, so
``warm_from_cache`` replays each cached winner once with synthetic
operands before the replica accepts traffic, populating the jit cache.

Warm-up is strictly best-effort: a cache entry that no longer matches
the installed kernels (renamed knob, impossible shape) is skipped, and
the replica starts regardless — the cache can make startup faster,
never break it.
"""
from __future__ import annotations

import numpy as np

from repro.tuning.cache import TuningCache


def _replay(key, config) -> None:
    import jax.numpy as jnp

    from repro.kernels import ops
    rng = np.random.default_rng(0)
    backend = key.backend
    if key.kernel == "fused_dense":
        rows, d_in, d_out = key.shape
        if key.dtype == "int8":
            x = jnp.asarray(rng.integers(-127, 127, size=(rows, d_in)),
                            jnp.int8)
            w = jnp.asarray(rng.integers(-127, 127, size=(d_in, d_out)),
                            jnp.int8)
            b = jnp.asarray(rng.normal(size=(d_out,)), jnp.float32)
            xs = jnp.asarray([[0.02]], jnp.float32)
            ws = jnp.asarray(rng.uniform(1e-3, 5e-2, size=(d_out,)),
                             jnp.float32)
            blocks = {k: v for k, v in config.items()
                      if k in ("bm", "bn", "bk")}
            out = ops.fused_dense_int8(x, w, b, xs, ws, backend=backend,
                                       **blocks)
        else:
            dt = jnp.bfloat16 if key.dtype == "bf16" else jnp.float32
            x = jnp.asarray(rng.normal(size=(rows, d_in)), dt)
            w = jnp.asarray(rng.normal(size=(d_in, d_out)), dt)
            b = jnp.asarray(rng.normal(size=(d_out,)), dt)
            out = ops.fused_dense(x, w, b, backend=backend, **config)
    elif key.kernel == "gravnet":
        if len(key.shape) == 5:    # batched problem: (batch, n, ds, df, k)
            batch, n, d_s, d_f, k = key.shape
            s = jnp.asarray(rng.normal(size=(batch, n, d_s)), jnp.float32)
            f = jnp.asarray(rng.normal(size=(batch, n, d_f)), jnp.float32)
            mask = jnp.ones((batch, n), jnp.float32)
            out = ops.gravnet_aggregate_batched(s, f, mask, k=k,
                                                backend=backend, **config)
        else:
            n, d_s, d_f, k = key.shape
            s = jnp.asarray(rng.normal(size=(n, d_s)), jnp.float32)
            f = jnp.asarray(rng.normal(size=(n, d_f)), jnp.float32)
            mask = jnp.ones((n,), jnp.float32)
            out = ops.gravnet_aggregate(s, f, mask, k=k, backend=backend,
                                        **config)
    elif key.kernel == "gravnet_block":
        cfg = dict(config)
        # the 5-dim key carries (batch, n, d_hidden, d_f, k); the
        # remaining block dims ride inside the cached config
        d_s = int(cfg.pop("d_s", 4))
        d_out = int(cfg.pop("d_out", 0))
        activation = cfg.pop("activation", "relu")
        concat_x = bool(cfg.pop("concat_x", True))
        if len(key.shape) == 5:
            batch, n, dh, d_f, k = key.shape
        else:
            n, dh, d_f, k = key.shape
            batch = 1
        d_out = d_out or dh
        dcat = dh + 2 * d_f if concat_x else 2 * d_f
        ws = jnp.asarray(rng.normal(size=(dh, d_s)) * 0.3, jnp.float32)
        bs = jnp.asarray(rng.normal(size=(d_s,)), jnp.float32)
        wf = jnp.asarray(rng.normal(size=(dh, d_f)) * 0.3, jnp.float32)
        bf = jnp.asarray(rng.normal(size=(d_f,)), jnp.float32)
        wo = jnp.asarray(rng.normal(size=(dcat, d_out)) * 0.3, jnp.float32)
        bo = jnp.asarray(rng.normal(size=(d_out,)), jnp.float32)
        if batch > 1:
            x = jnp.asarray(rng.normal(size=(batch, n, dh)), jnp.float32)
            mask = jnp.ones((batch, n), jnp.float32)
            out = ops.gravnet_block_batched(x, mask, ws, bs, wf, bf, wo,
                                            bo, k=k, activation=activation,
                                            concat_x=concat_x,
                                            backend=backend, **cfg)
        else:
            x = jnp.asarray(rng.normal(size=(n, dh)), jnp.float32)
            mask = jnp.ones((n,), jnp.float32)
            out = ops.gravnet_block(x, mask, ws, bs, wf, bf, wo, bo, k=k,
                                    activation=activation,
                                    concat_x=concat_x, backend=backend,
                                    **cfg)
    elif key.kernel == "gravnet_block_int8":
        cfg = dict(config)
        d_s = int(cfg.pop("d_s", 4))
        d_out = int(cfg.pop("d_out", 0))
        activation = cfg.pop("activation", "relu")
        concat_x = bool(cfg.pop("concat_x", True))
        if len(key.shape) == 5:
            batch, n, dh, d_f, k = key.shape
        else:
            n, dh, d_f, k = key.shape
            batch = 1
        d_out = d_out or dh
        dcat = dh + 2 * d_f if concat_x else 2 * d_f
        ws = jnp.asarray(rng.integers(-127, 128, size=(dh, d_s)), jnp.int8)
        wf = jnp.asarray(rng.integers(-127, 128, size=(dh, d_f)), jnp.int8)
        wo = jnp.asarray(rng.integers(-127, 128, size=(dcat, d_out)),
                         jnp.int8)
        bs = jnp.asarray(rng.normal(size=(d_s,)), jnp.float32)
        bf = jnp.asarray(rng.normal(size=(d_f,)), jnp.float32)
        bo = jnp.asarray(rng.normal(size=(d_out,)), jnp.float32)
        wss = jnp.asarray(rng.uniform(1e-3, 5e-2, size=(d_s,)), jnp.float32)
        wfs = jnp.asarray(rng.uniform(1e-3, 5e-2, size=(d_f,)), jnp.float32)
        wos = jnp.asarray(rng.uniform(1e-3, 5e-2, size=(d_out,)),
                          jnp.float32)
        # representative baked scales: warm-up only needs to hit the jit
        # cache for the launch shape/knobs, not the deployment's exact
        # calibration constants (those retrace once, at bind time)
        if batch > 1:
            x = jnp.asarray(rng.normal(size=(batch, n, dh)), jnp.float32)
            mask = jnp.ones((batch, n), jnp.float32)
            out = ops.gravnet_block_int8_batched(
                x, mask, ws, bs, wf, bf, wo, bo, wss, wfs, wos,
                x_scale=0.02, agg_scale=0.01, h_scale=0.02, k=k,
                activation=activation, concat_x=concat_x,
                backend=backend, **cfg)
        else:
            x = jnp.asarray(rng.normal(size=(n, dh)), jnp.float32)
            mask = jnp.ones((n,), jnp.float32)
            out = ops.gravnet_block_int8(
                x, mask, ws, bs, wf, bf, wo, bo, wss, wfs, wos,
                x_scale=0.02, agg_scale=0.01, h_scale=0.02, k=k,
                activation=activation, concat_x=concat_x,
                backend=backend, **cfg)
    elif key.kernel == "edge_aggregate":
        cfg = dict(config)
        reduce = cfg.pop("reduce", "sum")
        if len(key.shape) == 4:   # batched problem: (batch, n, e, d)
            batch, n, e, d = key.shape
            msgs = jnp.asarray(rng.normal(size=(batch, e, d)), jnp.float32)
            ei = jnp.asarray(rng.integers(0, n, size=(batch, 2, e)),
                             jnp.int32)
            mask = jnp.ones((batch, e), jnp.float32)
            out = ops.edge_aggregate_batched(msgs, ei, n, mask,
                                             reduce=reduce,
                                             backend=backend, **cfg)
        else:
            n, e, d = key.shape
            msgs = jnp.asarray(rng.normal(size=(e, d)), jnp.float32)
            ei = jnp.asarray(rng.integers(0, n, size=(2, e)), jnp.int32)
            mask = jnp.ones((e,), jnp.float32)
            out = ops.edge_aggregate(msgs, ei, n, mask, reduce=reduce,
                                     backend=backend, **cfg)
    elif key.kernel == "knn_build":
        if len(key.shape) == 4:   # batched problem: (batch, n, ds, k)
            batch, n, d_s, k = key.shape
            s = jnp.asarray(rng.normal(size=(batch, n, d_s)), jnp.float32)
            seg = jnp.zeros((batch, n), jnp.int32)
            out = ops.knn_build_batched(s, seg, k=k, backend=backend,
                                        **config)
        else:
            n, d_s, k = key.shape
            s = jnp.asarray(rng.normal(size=(n, d_s)), jnp.float32)
            seg = jnp.zeros((n,), jnp.int32)
            out = ops.knn_build(s, seg, k=k, backend=backend, **config)
    elif key.kernel == "knn_aggregate":
        cfg = dict(config)
        scale = float(cfg.pop("scale", 10.0))
        if len(key.shape) == 4:   # batched problem: (batch, n, df, k)
            batch, n, d_f, k = key.shape
            f = jnp.asarray(rng.normal(size=(batch, n, d_f)), jnp.float32)
            idx = jnp.asarray(rng.integers(0, n, size=(batch, n, k)),
                              jnp.int32)
            d2 = jnp.asarray(rng.uniform(0.0, 4.0, size=(batch, n, k)),
                             jnp.float32)
            out = ops.knn_aggregate_batched(f, idx, d2, scale=scale,
                                            backend=backend, **cfg)
        else:
            n, d_f, k = key.shape
            f = jnp.asarray(rng.normal(size=(n, d_f)), jnp.float32)
            idx = jnp.asarray(rng.integers(0, n, size=(n, k)), jnp.int32)
            d2 = jnp.asarray(rng.uniform(0.0, 4.0, size=(n, k)),
                             jnp.float32)
            out = ops.knn_aggregate(f, idx, d2, scale=scale,
                                    backend=backend, **cfg)
    elif key.kernel == "flash_attention":
        bh, s, t, d = key.shape
        q = jnp.asarray(rng.normal(size=(bh, s, d)), jnp.float32)
        kk = jnp.asarray(rng.normal(size=(bh, t, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(bh, t, d)), jnp.float32)
        out = ops.flash_attention(q, kk, v, backend=backend, **config)
    else:
        return
    import jax
    jax.block_until_ready(out)


def warm_from_cache(cache: TuningCache, *, backend: str | None = None,
                    kernels: tuple[str, ...] | None = None) -> int:
    """Replay every cached winner (optionally filtered by backend /
    kernel family) once; returns how many entries were warmed."""
    warmed = 0
    for key, entry in sorted(cache.entries().items(),
                             key=lambda kv: kv[0].encode()):
        if backend is not None and key.backend != backend:
            continue
        if kernels is not None and key.kernel not in kernels:
            continue
        try:
            _replay(key, entry.config)
        except Exception:   # noqa: BLE001 — stale entry must not block start
            continue
        warmed += 1
    return warmed


def make_warmup(cache: TuningCache, *, backend: str | None = None,
                kernels: tuple[str, ...] | None = None):
    """A no-arg callable for ``ReplicaEngine(warmup_fn=...)``."""
    def _warm():
        return warm_from_cache(cache, backend=backend, kernels=kernels)
    return _warm
