"""Candidate launch configurations for the tunable Pallas kernels.

The search spaces mirror the knobs the kernels actually expose:

- ``fused_dense``: the looped/flattened variant split (the paper's
  loop-pipelined vs ``chess_flatten_loop`` study) and the looped
  variant's ``(bm, bn, bk)`` block shapes;
- ``gravnet``: the row-tile ``bm`` (how many query rows per grid step
  share the VMEM-resident coordinate/feature operands);
- ``flash_attention``: the ``(bq, bk)`` q/kv block shapes.

Every candidate list starts with the **heuristic default** the code
would pick without tuning; the autotuner only switches away from it on
a measured, above-noise win, so an unlucky timing run can never make
things worse than today's behavior.

Block candidates are powers of two: the kernels' wrappers pad operands
to block multiples, TPU lanes are 128 wide, and sublane tiles are 8
deep — powers of two keep every candidate launchable on both the
interpret and Mosaic paths.
"""
from __future__ import annotations

from repro.core.passes import kernel_opt as _ko


def _pow2_range(lo: int, hi: int) -> list[int]:
    out = []
    v = lo
    while v <= hi:
        out.append(v)
        v *= 2
    return out


def _dedup_keep_order(cands: list[dict]) -> list[dict]:
    seen, out = set(), []
    for c in cands:
        sig = tuple(sorted(c.items()))
        if sig not in seen:
            seen.add(sig)
            out.append(c)
    return out


def default_fused_dense(rows: int, d_in: int, d_out: int) -> dict:
    """The untuned heuristic from ``kernel_opt`` (kept in one place so
    the bit-for-bit fallback and the search baseline cannot drift)."""
    if rows <= _ko.FLATTEN_ROWS and max(d_in, d_out) <= _ko.FLATTEN_DIM:
        return {"variant": "flattened"}
    return {"variant": "looped",
            "bm": _ko._pick_block(rows, 512),
            "bn": _ko._pick_block(d_out, 512),
            "bk": _ko._pick_block(d_in, 2048)}


def fused_dense_candidates(rows: int, d_in: int, d_out: int,
                           *, max_candidates: int = 16) -> list[dict]:
    cands = [default_fused_dense(rows, d_in, d_out)]
    # the flattened variant is only launchable when the whole operand
    # set fits VMEM comfortably; use the kernel_opt envelope ×2 so the
    # search can discover wins just past the heuristic cliff
    if rows <= 2 * _ko.FLATTEN_ROWS and max(d_in, d_out) <= _ko.FLATTEN_DIM:
        cands.append({"variant": "flattened"})
    bm_opts = [b for b in _pow2_range(8, 512) if b <= max(rows, 8)]
    bn_opts = [b for b in _pow2_range(128, 512) if b <= max(d_out, 128)]
    bk_opts = [b for b in _pow2_range(128, 2048) if b <= max(d_in, 128)]
    for bm in reversed(bm_opts[-3:]):        # largest row tiles first
        for bn in reversed(bn_opts[-2:]):
            for bk in reversed(bk_opts[-2:]):
                cands.append({"variant": "looped",
                              "bm": bm, "bn": bn, "bk": bk})
    return _dedup_keep_order(cands)[:max_candidates]


def default_fused_dense_int8(rows: int, d_in: int, d_out: int) -> dict:
    """The int8 executor path has no flattened variant; untuned it runs
    the looped kernel at the wrapper's default blocks."""
    return {"variant": "looped", "bm": 128, "bn": 128, "bk": 512}


def fused_dense_int8_candidates(rows: int, d_in: int, d_out: int,
                                *, max_candidates: int = 16) -> list[dict]:
    cands = [default_fused_dense_int8(rows, d_in, d_out)]
    cands += [c for c in fused_dense_candidates(rows, d_in, d_out)
              if c.get("variant") == "looped"]
    return _dedup_keep_order(cands)[:max_candidates]


def default_gravnet(n: int, batch: int = 1) -> dict:
    """The row-tile heuristic is per-event, so it is batch-invariant:
    the batched kernel's leading event grid dimension changes how many
    cells launch, not the cell's block shape."""
    return {"bm": min(n, 128)}


def gravnet_candidates(n: int, *, batch: int = 1,
                       max_candidates: int = 8) -> list[dict]:
    cands = [default_gravnet(n, batch)]
    for bm in _pow2_range(8, 512):
        if n % bm == 0:        # the kernel asserts n % bm == 0
            cands.append({"bm": bm})
    return _dedup_keep_order(cands)[:max_candidates]


def default_gravnet_block(n: int, batch: int = 1) -> dict:
    """Heuristic default for the fused block: the aggregation row tile
    (shared with the standalone gravnet kernel — batch-invariant) and a
    whole-operand epilogue (no bn/bk splits), which is the bitwise-safe
    configuration the executor uses on a cache miss."""
    return {"bm": min(n, 128)}


def gravnet_block_candidates(n: int, d_hidden: int, d_f: int, d_out: int,
                             *, concat_x: bool = True, batch: int = 1,
                             max_candidates: int = 10) -> list[dict]:
    """Search space for the megakernel: the row tile ``bm`` plus the
    epilogue's ``(bn, bk)`` blocking. ``bn`` splits output columns
    (bitwise-neutral); ``bk`` splits the epilogue K reduction (last-ulp
    f32 association may differ — it must win on measured time)."""
    cands = [default_gravnet_block(n, batch)]
    for bm in _pow2_range(8, 512):
        if n % bm == 0:        # the kernel asserts n % bm == 0
            cands.append({"bm": bm})
    bm0 = default_gravnet_block(n, batch)["bm"]
    dcat = d_hidden + 2 * d_f if concat_x else 2 * d_f
    for bn in _pow2_range(32, 256):
        if bn < d_out:
            cands.append({"bm": bm0, "bn": bn})
    for bk in _pow2_range(32, 256):
        if bk < dcat:
            cands.append({"bm": bm0, "bk": bk})
    return _dedup_keep_order(cands)[:max_candidates]


def default_gravnet_block_int8(n: int, batch: int = 1) -> dict:
    """Heuristic default for the quantized block: identical launch
    surface to the f32 megakernel (same row tile, whole-operand
    epilogue), so the untuned int8 binding mirrors the untuned f32
    one."""
    return {"bm": min(n, 128)}


def gravnet_block_int8_candidates(n: int, d_hidden: int, d_f: int,
                                  d_out: int, *, concat_x: bool = True,
                                  batch: int = 1,
                                  max_candidates: int = 10) -> list[dict]:
    """Search space for the quantized megakernel — the same (bm, bn,
    bk) knobs as the f32 block, searched under its own dtype-tagged
    key. One numerics difference widens the usable space: the epilogue
    accumulates in int32, so even ``bk`` K-splits are *exact* (no
    last-ulp caveat), and any measured winner is safe to bind."""
    cands = [default_gravnet_block_int8(n, batch)]
    for bm in _pow2_range(8, 512):
        if n % bm == 0:        # the kernel asserts n % bm == 0
            cands.append({"bm": bm})
    bm0 = default_gravnet_block_int8(n, batch)["bm"]
    dcat = d_hidden + 2 * d_f if concat_x else 2 * d_f
    for bn in _pow2_range(32, 256):
        if bn < d_out:
            cands.append({"bm": bm0, "bn": bn})
    for bk in _pow2_range(32, 256):
        if bk < dcat:
            cands.append({"bm": bm0, "bk": bk})
    return _dedup_keep_order(cands)[:max_candidates]


def default_edge_aggregate(n: int, e: int, batch: int = 1) -> dict:
    """Heuristic default for the edge-aggregation kernel: the gravnet
    row-tile rule (batch-invariant) and a single whole-edge-set chunk —
    the configuration the executor uses on a cache miss."""
    return {"bm": min(n, 128)}


def edge_aggregate_candidates(n: int, e: int, *, batch: int = 1,
                              max_candidates: int = 10) -> list[dict]:
    """Search space: the destination row tile ``bm`` plus the edge-axis
    chunk ``be``. ``be`` splits the f32 accumulation into ordered
    chunks (association may move last ulps — it must win on measured
    time, like fused-dense ``bk``)."""
    cands = [default_edge_aggregate(n, e, batch)]
    for bm in _pow2_range(8, 512):
        if n % bm == 0:        # the kernel asserts n % bm == 0
            cands.append({"bm": bm})
    bm0 = default_edge_aggregate(n, e, batch)["bm"]
    for be in _pow2_range(128, 2048):
        if be < e and e % be == 0:   # the kernel asserts e % be == 0
            cands.append({"bm": bm0, "be": be})
    return _dedup_keep_order(cands)[:max_candidates]


def default_knn_build(n: int, batch: int = 1) -> dict:
    """Heuristic default for the ragged kNN kernels: the gravnet
    row-tile rule (batch-invariant — the batched form only adds a
    leading bin grid dimension)."""
    return {"bm": min(n, 128)}


def knn_build_candidates(n: int, *, batch: int = 1,
                         max_candidates: int = 8) -> list[dict]:
    cands = [default_knn_build(n, batch)]
    for bm in _pow2_range(8, 512):
        if n % bm == 0:        # the kernel asserts n % bm == 0
            cands.append({"bm": bm})
    return _dedup_keep_order(cands)[:max_candidates]


def default_knn_aggregate(n: int, batch: int = 1) -> dict:
    return {"bm": min(n, 128)}


def knn_aggregate_candidates(n: int, *, batch: int = 1,
                             max_candidates: int = 8) -> list[dict]:
    cands = [default_knn_aggregate(n, batch)]
    for bm in _pow2_range(8, 512):
        if n % bm == 0:        # the kernel asserts n % bm == 0
            cands.append({"bm": bm})
    return _dedup_keep_order(cands)[:max_candidates]


def default_flash_attention() -> dict:
    return {"bq": 128, "bk": 128}


def flash_attention_candidates(s: int, t: int,
                               *, max_candidates: int = 8) -> list[dict]:
    cands = [default_flash_attention()]
    for bq in _pow2_range(64, 256):
        for bk in _pow2_range(64, 256):
            cands.append({"bq": min(bq, s), "bk": min(bk, t)})
    return _dedup_keep_order(cands)[:max_candidates]
