"""Kernel autotuner: measure candidate configs, persist winners.

``tune_*`` functions benchmark one kernel at one problem shape through
the public ``repro.kernels.ops`` wrappers (so padding, jit, and backend
dispatch cost exactly what production calls cost) and write the winner
into a ``TuningCache``. ``autotune_graph`` walks a deploy-optimized IR
graph and tunes every kernel shape the pipeline actually emits — the
shapes are derived by the same rules ``kernel_opt`` uses to bind
kernels, so a subsequent ``deploy(..., tuning_cache=...)`` hits every
entry.

The default candidate (today's heuristic) is always measured first and
only dethroned by a ``min_gain`` relative win (default 3%), so timer
noise can never tune the pipeline *below* its untuned performance.

On the ``'xla'`` backend the kernel wrappers take the jnp reference
path and *ignore* every launch knob (variant/blocks), so searching
there would time N identical programs and record noise as winners.
Knob-inert backends therefore record the heuristic default only
(one measurement — the cache entry still drives serving warm-up at
the right shapes); the real search runs on ``'pallas'`` /
``'pallas_interpret'`` where the knobs change the launched kernel.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.tuning import candidates as cand
from repro.tuning.cache import (KernelKey, TuningCache, edge_aggregate_key,
                                flash_attention_key, fused_dense_key,
                                gravnet_block_int8_key, gravnet_block_key,
                                gravnet_key, knn_aggregate_key,
                                knn_build_key)

MIN_GAIN = 0.03

# backends whose ops wrappers ignore launch knobs (jnp reference path):
# tuning degenerates to timing the default config once
_KNOB_INERT_BACKENDS = frozenset({"xla"})


def _time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Min seconds per call with block_until_ready. Min, not median:
    scheduler noise on a busy host is strictly additive, so the minimum
    is the least-noisy estimator of the kernel's intrinsic cost."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))


def _np_dtype(dtype: str):
    import jax.numpy as jnp
    return {"float32": jnp.float32, "bf16": jnp.bfloat16,
            "int8": jnp.int8}.get(dtype, jnp.float32)


def _pick(timed: list[tuple[dict, float]], *, min_gain: float):
    """timed[0] is the heuristic default; a challenger must beat it by
    ``min_gain`` relative to win."""
    default_cfg, default_t = timed[0]
    best_cfg, best_t = default_cfg, default_t
    for cfg, t in timed[1:]:
        if t < best_t:
            best_cfg, best_t = cfg, t
    if best_t >= default_t * (1.0 - min_gain):
        best_cfg, best_t = default_cfg, default_t
    return best_cfg, best_t, default_t


def _finish(cache: TuningCache | None, key: KernelKey, timed,
            *, min_gain: float) -> dict:
    best_cfg, best_t, default_t = _pick(timed, min_gain=min_gain)
    if cache is not None:
        cache.put(key, best_cfg, us=best_t * 1e6, default_us=default_t * 1e6,
                  candidates=len(timed))
    return best_cfg


# ------------------------------------------------------------ fused dense ----
def tune_fused_dense(rows: int, d_in: int, d_out: int, *,
                     dtype: str = "float32", backend: str = "xla",
                     cache: TuningCache | None = None, iters: int = 5,
                     min_gain: float = MIN_GAIN, seed: int = 0) -> dict:
    import jax.numpy as jnp

    from repro.kernels import ops
    rng = np.random.default_rng(seed)
    dt = _np_dtype(dtype)
    if dtype == "int8":
        x = jnp.asarray(rng.integers(-127, 127, size=(rows, d_in)), jnp.int8)
        w = jnp.asarray(rng.integers(-127, 127, size=(d_in, d_out)), jnp.int8)
        b = jnp.asarray(rng.normal(size=(d_out,)), jnp.float32)
        xs = jnp.asarray([[0.02]], jnp.float32)
        ws = jnp.asarray(rng.uniform(1e-3, 5e-2, size=(d_out,)), jnp.float32)

        def call(cfg):
            blocks = {k: v for k, v in cfg.items() if k in ("bm", "bn", "bk")}
            return ops.fused_dense_int8(x, w, b, xs, ws, backend=backend,
                                        **blocks)
    else:
        x = jnp.asarray(rng.normal(size=(rows, d_in)), dt)
        w = jnp.asarray(rng.normal(size=(d_in, d_out)), dt)
        b = jnp.asarray(rng.normal(size=(d_out,)), dt)

        def call(cfg):
            return ops.fused_dense(x, w, b, backend=backend, **cfg)

    if dtype == "int8":   # the int8 kernel has no flattened variant
        cands = cand.fused_dense_int8_candidates(rows, d_in, d_out)
    else:
        cands = cand.fused_dense_candidates(rows, d_in, d_out)
    if backend in _KNOB_INERT_BACKENDS:
        cands = cands[:1]
    timed = [(cfg, _time_call(lambda c=cfg: call(c), iters=iters))
             for cfg in cands]
    key = fused_dense_key(rows, d_in, d_out, dtype, backend)
    return _finish(cache, key, timed, min_gain=min_gain)


# ---------------------------------------------------------------- gravnet ----
def tune_gravnet(n: int, d_s: int, d_f: int, k: int, *,
                 batch: int = 1, dtype: str = "float32",
                 backend: str = "xla", cache: TuningCache | None = None,
                 iters: int = 5, min_gain: float = MIN_GAIN,
                 seed: int = 0) -> dict:
    """``batch > 1`` tunes the *batched* kernel (leading event grid
    dimension) at the (batch, n) shape a bucketed deployment actually
    launches; batch=1 keeps the legacy per-event problem/key."""
    import jax.numpy as jnp

    from repro.kernels import ops
    rng = np.random.default_rng(seed)
    dt = _np_dtype(dtype)
    if batch > 1:
        s = jnp.asarray(rng.normal(size=(batch, n, d_s)), dt)
        f = jnp.asarray(rng.normal(size=(batch, n, d_f)), dt)
        mask = jnp.asarray(rng.uniform(size=(batch, n)) < 0.8, jnp.float32)

        def call(cfg):
            return ops.gravnet_aggregate_batched(s, f, mask, k=k,
                                                 backend=backend, **cfg)
    else:
        s = jnp.asarray(rng.normal(size=(n, d_s)), dt)
        f = jnp.asarray(rng.normal(size=(n, d_f)), dt)
        mask = jnp.asarray(rng.uniform(size=(n,)) < 0.8, jnp.float32)

        def call(cfg):
            return ops.gravnet_aggregate(s, f, mask, k=k, backend=backend,
                                         **cfg)

    cands = cand.gravnet_candidates(n, batch=batch)
    if backend in _KNOB_INERT_BACKENDS:
        cands = cands[:1]
    timed = [(cfg, _time_call(lambda c=cfg: call(c), iters=iters))
             for cfg in cands]
    key = gravnet_key(n, d_s, d_f, k, dtype, backend, batch=batch)
    return _finish(cache, key, timed, min_gain=min_gain)


# ------------------------------------------------------------ gravnet block ----
def tune_gravnet_block(n: int, d_hidden: int, d_s: int, d_f: int,
                       d_out: int, k: int, *, batch: int = 1,
                       activation: str = "relu", concat_x: bool = True,
                       dtype: str = "float32", backend: str = "xla",
                       cache: TuningCache | None = None, iters: int = 5,
                       min_gain: float = MIN_GAIN, seed: int = 0) -> dict:
    """Tune the fused GravNet-block megakernel at one problem shape.

    The 5-dim key carries (batch, n, d_hidden, d_f, k); the remaining
    block dims (d_s, d_out, activation, concat_x) are stored inside the
    cached config so serving warm-up can replay the exact problem —
    ``kernel_opt`` only ever binds the (bm, bn, bk) knobs.
    ``dtype="int8"`` tunes the quantized megakernel (int8 weights with
    per-channel scales, representative baked activation scales) under
    its own ``gravnet_block_int8`` key family."""
    import jax.numpy as jnp

    from repro.kernels import ops
    rng = np.random.default_rng(seed)
    dcat = d_hidden + 2 * d_f if concat_x else 2 * d_f
    if dtype == "int8":
        ws = jnp.asarray(rng.integers(-127, 128, size=(d_hidden, d_s)),
                         jnp.int8)
        wf = jnp.asarray(rng.integers(-127, 128, size=(d_hidden, d_f)),
                         jnp.int8)
        wo = jnp.asarray(rng.integers(-127, 128, size=(dcat, d_out)),
                         jnp.int8)
        bs = jnp.asarray(rng.normal(size=(d_s,)), jnp.float32)
        bf = jnp.asarray(rng.normal(size=(d_f,)), jnp.float32)
        bo = jnp.asarray(rng.normal(size=(d_out,)), jnp.float32)
        wss = jnp.asarray(rng.uniform(1e-3, 5e-2, size=(d_s,)), jnp.float32)
        wfs = jnp.asarray(rng.uniform(1e-3, 5e-2, size=(d_f,)), jnp.float32)
        wos = jnp.asarray(rng.uniform(1e-3, 5e-2, size=(d_out,)),
                          jnp.float32)
        shape = (batch, n, d_hidden) if batch > 1 else (n, d_hidden)
        x = jnp.asarray(rng.normal(size=shape), jnp.float32)
        mshape = (batch, n) if batch > 1 else (n,)
        mask = jnp.asarray(rng.uniform(size=mshape) < 0.8, jnp.float32)
        fn = (ops.gravnet_block_int8_batched if batch > 1
              else ops.gravnet_block_int8)

        def call(cfg):
            return fn(x, mask, ws, bs, wf, bf, wo, bo, wss, wfs, wos,
                      x_scale=0.02, agg_scale=0.01, h_scale=0.02, k=k,
                      activation=activation, concat_x=concat_x,
                      backend=backend, **cfg)

        cands = cand.gravnet_block_int8_candidates(
            n, d_hidden, d_f, d_out, concat_x=concat_x, batch=batch)
        key = gravnet_block_int8_key(n, d_hidden, d_f, k, backend,
                                     batch=batch)
    else:
        dt = _np_dtype(dtype)
        ws = jnp.asarray(rng.normal(size=(d_hidden, d_s)) * 0.3, dt)
        bs = jnp.asarray(rng.normal(size=(d_s,)), dt)
        wf = jnp.asarray(rng.normal(size=(d_hidden, d_f)) * 0.3, dt)
        bf = jnp.asarray(rng.normal(size=(d_f,)), dt)
        wo = jnp.asarray(rng.normal(size=(dcat, d_out)) * 0.3, dt)
        bo = jnp.asarray(rng.normal(size=(d_out,)), dt)
        shape = (batch, n, d_hidden) if batch > 1 else (n, d_hidden)
        x = jnp.asarray(rng.normal(size=shape), dt)
        mshape = (batch, n) if batch > 1 else (n,)
        mask = jnp.asarray(rng.uniform(size=mshape) < 0.8, jnp.float32)
        fn = ops.gravnet_block_batched if batch > 1 else ops.gravnet_block

        def call(cfg):
            return fn(x, mask, ws, bs, wf, bf, wo, bo, k=k,
                      activation=activation, concat_x=concat_x,
                      backend=backend, **cfg)

        cands = cand.gravnet_block_candidates(
            n, d_hidden, d_f, d_out, concat_x=concat_x, batch=batch)
        key = gravnet_block_key(n, d_hidden, d_f, k, dtype, backend,
                                batch=batch)
    if backend in _KNOB_INERT_BACKENDS:
        cands = cands[:1]
    timed = [(cfg, _time_call(lambda c=cfg: call(c), iters=iters))
             for cfg in cands]
    best_cfg, best_t, default_t = _pick(timed, min_gain=min_gain)
    if cache is not None:
        cache.put(key, {**best_cfg, "d_s": d_s, "d_out": d_out,
                        "activation": activation, "concat_x": concat_x},
                  us=best_t * 1e6, default_us=default_t * 1e6,
                  candidates=len(timed))
    return best_cfg


# --------------------------------------------------------- edge aggregate ----
def tune_edge_aggregate(n: int, e: int, d: int, *, reduce: str = "sum",
                        batch: int = 1, dtype: str = "float32",
                        backend: str = "xla",
                        cache: TuningCache | None = None, iters: int = 5,
                        min_gain: float = MIN_GAIN, seed: int = 0) -> dict:
    """Tune the edge-aggregation kernel at one (n, e, d) problem shape.
    ``reduce`` rides inside the cached config (like the gravnet-block
    extras) so serving warm-up can replay the exact problem; the binder
    only ever reads the (bm, be) knobs."""
    import jax.numpy as jnp

    from repro.kernels import ops
    rng = np.random.default_rng(seed)
    dt = _np_dtype(dtype)
    if batch > 1:
        msgs = jnp.asarray(rng.normal(size=(batch, e, d)), dt)
        ei = jnp.asarray(rng.integers(0, n, size=(batch, 2, e)), jnp.int32)
        mask = jnp.asarray(rng.uniform(size=(batch, e)) < 0.8, jnp.float32)

        def call(cfg):
            return ops.edge_aggregate_batched(msgs, ei, n, mask,
                                              reduce=reduce,
                                              backend=backend, **cfg)
    else:
        msgs = jnp.asarray(rng.normal(size=(e, d)), dt)
        ei = jnp.asarray(rng.integers(0, n, size=(2, e)), jnp.int32)
        mask = jnp.asarray(rng.uniform(size=(e,)) < 0.8, jnp.float32)

        def call(cfg):
            return ops.edge_aggregate(msgs, ei, n, mask, reduce=reduce,
                                      backend=backend, **cfg)

    cands = cand.edge_aggregate_candidates(n, e, batch=batch)
    if backend in _KNOB_INERT_BACKENDS:
        cands = cands[:1]
    timed = [(cfg, _time_call(lambda c=cfg: call(c), iters=iters))
             for cfg in cands]
    key = edge_aggregate_key(n, e, d, dtype, backend, batch=batch)
    best_cfg, best_t, default_t = _pick(timed, min_gain=min_gain)
    if cache is not None:
        cache.put(key, {**best_cfg, "reduce": reduce}, us=best_t * 1e6,
                  default_us=default_t * 1e6, candidates=len(timed))
    return best_cfg


# ------------------------------------------------------------- ragged kNN ----
def _ragged_segids(rng, shape) -> np.ndarray:
    """Representative bin-packed segment ids: a few contiguous events
    per bin with a padded tail (the layout ``data/ragged.bin_pack``
    emits), so tuning measurements see realistic masking."""
    n = shape[-1]
    seg = np.full(shape, -1, np.int32)
    flat = seg.reshape(-1, n)
    for row in flat:
        fill = int(rng.integers(n // 2, n + 1))
        cuts = np.sort(rng.choice(np.arange(1, fill), size=min(2, fill - 1),
                                  replace=False)) if fill > 2 else []
        prev, ev = 0, 0
        for c in list(cuts) + [fill]:
            row[prev:c] = ev
            prev, ev = c, ev + 1
    return seg


def tune_knn_build(n: int, d_s: int, k: int, *, batch: int = 1,
                   dtype: str = "float32", backend: str = "xla",
                   cache: TuningCache | None = None, iters: int = 5,
                   min_gain: float = MIN_GAIN, seed: int = 0) -> dict:
    """Tune the ragged-path neighbor-selection kernel. ``n`` is the bin
    capacity, ``batch`` the bin count of the batched launch."""
    import jax.numpy as jnp

    from repro.kernels import ops
    rng = np.random.default_rng(seed)
    dt = _np_dtype(dtype)
    if batch > 1:
        s = jnp.asarray(rng.normal(size=(batch, n, d_s)), dt)
        seg = jnp.asarray(_ragged_segids(rng, (batch, n)))

        def call(cfg):
            return ops.knn_build_batched(s, seg, k=k, backend=backend,
                                         **cfg)
    else:
        s = jnp.asarray(rng.normal(size=(n, d_s)), dt)
        seg = jnp.asarray(_ragged_segids(rng, (1, n))[0])

        def call(cfg):
            return ops.knn_build(s, seg, k=k, backend=backend, **cfg)

    cands = cand.knn_build_candidates(n, batch=batch)
    if backend in _KNOB_INERT_BACKENDS:
        cands = cands[:1]
    timed = [(cfg, _time_call(lambda c=cfg: call(c), iters=iters))
             for cfg in cands]
    key = knn_build_key(n, d_s, k, dtype, backend, batch=batch)
    return _finish(cache, key, timed, min_gain=min_gain)


def tune_knn_aggregate(n: int, d_f: int, k: int, *, batch: int = 1,
                       scale: float = 10.0, dtype: str = "float32",
                       backend: str = "xla",
                       cache: TuningCache | None = None, iters: int = 5,
                       min_gain: float = MIN_GAIN, seed: int = 0) -> dict:
    """Tune the ragged-path aggregation kernel over representative
    knn_build outputs (``scale`` rides inside the cached config so
    warm-up can replay the exact problem)."""
    import jax.numpy as jnp

    from repro.kernels import ops
    rng = np.random.default_rng(seed)
    dt = _np_dtype(dtype)
    if batch > 1:
        f = jnp.asarray(rng.normal(size=(batch, n, d_f)), dt)
        idx = jnp.asarray(rng.integers(0, n, size=(batch, n, k)), jnp.int32)
        d2 = jnp.asarray(rng.uniform(0.0, 4.0, size=(batch, n, k)),
                         jnp.float32)

        def call(cfg):
            return ops.knn_aggregate_batched(f, idx, d2, scale=scale,
                                             backend=backend, **cfg)
    else:
        f = jnp.asarray(rng.normal(size=(n, d_f)), dt)
        idx = jnp.asarray(rng.integers(0, n, size=(n, k)), jnp.int32)
        d2 = jnp.asarray(rng.uniform(0.0, 4.0, size=(n, k)), jnp.float32)

        def call(cfg):
            return ops.knn_aggregate(f, idx, d2, scale=scale,
                                     backend=backend, **cfg)

    cands = cand.knn_aggregate_candidates(n, batch=batch)
    if backend in _KNOB_INERT_BACKENDS:
        cands = cands[:1]
    timed = [(cfg, _time_call(lambda c=cfg: call(c), iters=iters))
             for cfg in cands]
    key = knn_aggregate_key(n, d_f, k, dtype, backend, batch=batch)
    best_cfg, best_t, default_t = _pick(timed, min_gain=min_gain)
    if cache is not None:
        cache.put(key, {**best_cfg, "scale": scale}, us=best_t * 1e6,
                  default_us=default_t * 1e6, candidates=len(timed))
    return best_cfg


# -------------------------------------------------------- flash attention ----
def tune_flash_attention(bh: int, s: int, t: int, d: int, *,
                         causal: bool = True, dtype: str = "float32",
                         backend: str = "xla",
                         cache: TuningCache | None = None, iters: int = 5,
                         min_gain: float = MIN_GAIN, seed: int = 0) -> dict:
    import jax.numpy as jnp

    from repro.kernels import ops
    rng = np.random.default_rng(seed)
    dt = _np_dtype(dtype)
    q = jnp.asarray(rng.normal(size=(bh, s, d)), dt)
    k = jnp.asarray(rng.normal(size=(bh, t, d)), dt)
    v = jnp.asarray(rng.normal(size=(bh, t, d)), dt)

    def call(cfg):
        return ops.flash_attention(q, k, v, causal=causal, backend=backend,
                                   **cfg)

    cands = cand.flash_attention_candidates(s, t)
    if backend in _KNOB_INERT_BACKENDS:
        cands = cands[:1]
    timed = [(cfg, _time_call(lambda c=cfg: call(c), iters=iters))
             for cfg in cands]
    key = flash_attention_key(bh, s, t, d, dtype, backend)
    return _finish(cache, key, timed, min_gain=min_gain)


# ------------------------------------------------------------ graph walk ----
def graph_kernel_problems(g, *, n_rows: int, backend: str,
                          batch: int = 1) -> list[KernelKey]:
    """The tuning problems a deploy-optimized graph emits, derived
    through the registry's per-spec tuning-key hooks
    (``op_registry.tuning_problem``) — the exact hooks ``kernel_opt``'s
    binders key the cache with, so a subsequent deploy hits every
    entry. ``batch`` is the packed micro-batch width of a bucketed
    executable (1 = legacy per-event shapes)."""
    from repro.core.op_registry import tuning_problem
    problems: list[KernelKey] = []
    seen: set[KernelKey] = set()
    for op in g:
        key = tuning_problem(op, n_rows=n_rows, backend=backend,
                             batch=batch)
        if key is not None and key not in seen:
            seen.add(key)
            problems.append(key)
    return problems


def autotune_graph(g, *, n_rows: int, backend: str, cache: TuningCache,
                   batch: int = 1, iters: int = 5,
                   min_gain: float = MIN_GAIN, force: bool = False,
                   verbose: bool = False) -> int:
    """Tune every kernel problem in ``g``; returns how many were
    (re)searched. Existing cache entries are kept unless ``force``."""
    tuned = 0
    for key in graph_kernel_problems(g, n_rows=n_rows, backend=backend,
                                     batch=batch):
        if not force and key in cache:
            continue
        if key.kernel == "fused_dense":
            rows, d_in, d_out = key.shape
            tune_fused_dense(rows, d_in, d_out, dtype=key.dtype,
                             backend=backend, cache=cache, iters=iters,
                             min_gain=min_gain)
        elif key.kernel == "gravnet":
            shape = key.shape
            kb = shape[0] if len(shape) == 5 else 1
            n, d_s, d_f, k = shape[-4:]
            tune_gravnet(n, d_s, d_f, k, batch=kb, dtype=key.dtype,
                         backend=backend, cache=cache, iters=iters,
                         min_gain=min_gain)
        elif key.kernel in ("gravnet_block", "gravnet_block_int8"):
            shape = key.shape
            kb = shape[0] if len(shape) == 5 else 1
            n, dh, d_f, k = shape[-4:]
            # recover the dims the 5-dim key doesn't carry from the op
            extras = {"d_s": 4, "d_out": dh, "activation": "relu",
                      "concat_x": True}
            for op in g:
                if (op.op_type == "gravnet_block"
                        and op.attrs.get("d_hidden") == dh
                        and op.attrs.get("d_f") == d_f
                        and op.attrs.get("k") == k):
                    extras = {
                        "d_s": op.attrs["d_s"],
                        "d_out": op.out_dim or dh,
                        "activation": op.attrs.get("activation", "relu"),
                        "concat_x": op.attrs.get("concat_x", True)}
                    break
            tune_gravnet_block(n, dh, extras["d_s"], d_f,
                               extras["d_out"], k, batch=kb,
                               activation=extras["activation"],
                               concat_x=extras["concat_x"],
                               dtype=key.dtype, backend=backend,
                               cache=cache, iters=iters,
                               min_gain=min_gain)
        elif key.kernel == "edge_aggregate":
            shape = key.shape
            kb = shape[0] if len(shape) == 4 else 1
            n, e, d = shape[-3:]
            # recover the reduction mode the shape key doesn't carry
            reduce = "sum"
            for op in g:
                if (op.op_type == "edge_aggregate"
                        and (op.out_dim or 1) == d):
                    reduce = op.attrs.get("reduce", "sum")
                    break
            tune_edge_aggregate(n, e, d, reduce=reduce, batch=kb,
                                dtype=key.dtype, backend=backend,
                                cache=cache, iters=iters,
                                min_gain=min_gain)
        elif key.kernel == "knn_build":
            shape = key.shape
            kb = shape[0] if len(shape) == 4 else 1
            n, d_s, k = shape[-3:]
            tune_knn_build(n, d_s, k, batch=kb, dtype=key.dtype,
                           backend=backend, cache=cache, iters=iters,
                           min_gain=min_gain)
        elif key.kernel == "knn_aggregate":
            shape = key.shape
            kb = shape[0] if len(shape) == 4 else 1
            n, d_f, k = shape[-3:]
            scale = 10.0
            for op in g:
                if (op.op_type == "knn_aggregate"
                        and op.attrs.get("d_f") == d_f
                        and op.attrs.get("k") == k):
                    scale = op.attrs.get("scale", 10.0)
                    break
            tune_knn_aggregate(n, d_f, k, scale=scale, batch=kb,
                               dtype=key.dtype, backend=backend,
                               cache=cache, iters=iters,
                               min_gain=min_gain)
        elif key.kernel == "flash_attention":
            bh, s, t, d = key.shape
            tune_flash_attention(bh, s, t, d, dtype=key.dtype,
                                 backend=backend, cache=cache,
                                 iters=iters, min_gain=min_gain)
        else:
            continue
        tuned += 1
        if verbose:
            e = cache.entry(key)
            print(f"[tune] {key.encode()} -> {e.config} "
                  f"({e.us:.1f}us vs default {e.default_us:.1f}us, "
                  f"{e.candidates} candidates)")
    return tuned
