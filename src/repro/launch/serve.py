"""Serving driver: ``python -m repro.launch.serve [...]``.

Runs a registered model end to end on CPU: deploy it through the
model-agnostic design flow at the chosen design point (the model joins
via its ``core.graph_ir`` exporter — serve.py has no model-specific
imports at module level), wrap the compiled pipeline in the real-time
sharded trigger service (micro-batching window, strict in-order
completion, hedged dispatch), stream synthetic events through it, and
report throughput/latency percentiles.

``--model`` picks the route(s) from the serve-side model registry
(``MODELS``; default ``ccn``). The single-model ``ccn`` selection runs
the paper's full demonstrator — brief condensation training, the
monitoring pipeline (paper §III-B: online ``MonitorSnapshot`` with
truth-matched efficiency/fake-rate, optional ``--monitor-port`` HTTP
endpoint, JSON event display), and the ``--buckets`` occupancy path.
Any other selection serves the named models side by side through
per-route replica groups (``ShardedTriggerService(routes=...)``) — the
CCN trigger next to the edge-based GNNs — and can write a
``--bench-out`` JSON with per-route serving stats.

``--buckets`` switches to the occupancy-bucketed path: one batch-packed
executable per n_hits tier (``deploy_bucketed``), each event dispatched
to the smallest bucket that fits its non-zero hit count, every bucket
pre-compiled before traffic — see docs/architecture.md.

Replicas run the persistent **streaming dataflow loop** by default
(rolling batching into preallocated rings, no deadline tick);
``--loop deadline`` is the escape hatch reproducing the original
micro-batch deadline loop exactly — see docs/serving.md.
"""
from __future__ import annotations

import argparse
import json
import time
import urllib.request
from typing import Callable, NamedTuple

import jax
import numpy as np

from repro.core.graph_ir import export_graph
from repro.core.passes.parallelize import Requirements
from repro.core.pipeline import deploy, deploy_bucketed
from repro.serving import (FaultPlan, MonitorServer,
                           ShardedTriggerService, event_display,
                           write_display)


# ------------------------------------------------------------ model zoo ----
class Servable(NamedTuple):
    """One deployed route: the compiled pipeline plus a synthetic
    per-event feed source matching its input features."""
    name: str
    pipe: Callable
    events: Callable      # (n, seed) -> list of per-event feed dicts


_EDGE_N, _EDGE_E = 64, 256     # E = 4N, the registry's edge budget


def _edge_events(d_in, d_edge_in=None):
    def events(n, seed):
        rng = np.random.default_rng(seed)
        out = []
        for _ in range(n):
            ev = {
                "nodes": rng.normal(
                    size=(_EDGE_N, d_in)).astype(np.float32),
                "edge_index": rng.integers(
                    0, _EDGE_N, size=(2, _EDGE_E)).astype(np.int32),
                "node_mask": (rng.uniform(size=(_EDGE_N,)) < 0.8)
                .astype(np.float32),
                "edge_mask": (rng.uniform(size=(_EDGE_E,)) < 0.7)
                .astype(np.float32),
            }
            if d_edge_in is not None:
                ev["edges"] = rng.normal(
                    size=(_EDGE_E, d_edge_in)).astype(np.float32)
            out.append(ev)
        return out
    return events


def _ccn_servable(args) -> Servable:
    from repro.core import caloclusternet as ccn
    from repro.data.belle2 import (Belle2Config, current_detector,
                                   generate)
    if args.detector == "current":
        cfg, gen_cfg = ccn.current_detector_config(), current_detector()
    else:
        cfg, gen_cfg = ccn.CCNConfig(), Belle2Config()
    params = ccn.init(jax.random.PRNGKey(0), cfg)
    graph = export_graph("caloclusternet", params, cfg)
    calib = generate(gen_cfg, 64, seed=123)
    req = Requirements(design_point=args.design_point, platform="cpu",
                       precision_policy=args.precision,
                       n_hits=cfg.n_hits,
                       target_throughput=args.target_throughput,
                       max_latency_s=2e-3)
    pipe = deploy(graph, req, calibration_feeds={
        "hits": calib["feats"], "mask": calib["mask"]})

    def events(n, seed):
        ev = generate(gen_cfg, n, seed=seed)
        return [{"hits": ev["feats"][i], "mask": ev["mask"][i]}
                for i in range(n)]

    return Servable("ccn", pipe, events)


def _gatedgcn_servable(args) -> Servable:
    from repro.models.gnn import gatedgcn
    cfg = gatedgcn.GatedGCNConfig(n_layers=4, d_hidden=32, d_in=8,
                                  d_edge_in=4, n_classes=2)
    params = gatedgcn.init(jax.random.PRNGKey(1), cfg)
    graph = export_graph("gatedgcn", params, cfg)
    req = Requirements(design_point=args.design_point, platform="cpu",
                       precision_policy="fp", n_hits=_EDGE_N,
                       target_throughput=args.target_throughput,
                       max_latency_s=2e-3)
    return Servable("gatedgcn", deploy(graph, req),
                    _edge_events(cfg.d_in, cfg.d_edge_in))


def _graphsage_servable(args) -> Servable:
    from repro.models.gnn import graphsage
    cfg = graphsage.GraphSAGEConfig(n_layers=2, d_hidden=32, d_in=16,
                                    n_classes=5)
    params = graphsage.init(jax.random.PRNGKey(2), cfg)
    graph = export_graph("graphsage", params, cfg)
    req = Requirements(design_point=args.design_point, platform="cpu",
                       precision_policy="fp", n_hits=_EDGE_N,
                       target_throughput=args.target_throughput,
                       max_latency_s=2e-3)
    return Servable("graphsage", deploy(graph, req),
                    _edge_events(cfg.d_in))


MODELS: dict[str, Callable] = {
    "ccn": _ccn_servable,
    "gatedgcn": _gatedgcn_servable,
    "graphsage": _graphsage_servable,
}


def _tune_and_rebind(cache, args, problems, redeploy):
    """Autotune the given (graph, n_rows, batch, backend) problems,
    persist winners, and redeploy with them bound; returns the fresh
    deployment or None when nothing new was searched."""
    from repro.tuning import autotune_graph
    n_new = sum(autotune_graph(g, n_rows=nr, batch=bt, backend=be,
                               cache=cache, verbose=True)
                for g, nr, bt, be in problems)
    print(f"[serve] autotuned {n_new} kernel problem(s), "
          f"cache holds {len(cache)}")
    if args.tuning_cache:
        cache.save(args.tuning_cache)
        print(f"[serve] tuning cache -> {args.tuning_cache}")
    return redeploy() if n_new else None   # rebind fresh winners


def _fault_kwargs(args) -> dict:
    """Fault-tolerance service kwargs from the CLI: a seeded fault
    plan (--inject-faults implies the breaker — injecting chaos
    without health tracking just loses events), circuit breaking,
    bounded failover, and load shedding."""
    faults = FaultPlan.parse(args.inject_faults, seed=args.fault_seed) \
        if args.inject_faults else None
    if faults is not None:
        print(f"[serve] chaos plan: {faults.describe()}")
    return {"faults": faults,
            "breaker": args.breaker or faults is not None,
            "max_retries": args.max_retries,
            "shed": args.shed}


def _print_chaos(eng, failed: int):
    ft = eng.fault_tolerance_summary()
    br = ft["breaker"]
    print(f"[serve] chaos: {failed} client-visible failure(s), "
          f"shed={ft['shed']} retried={ft['retried']} "
          f"failed_over={ft['failed_over']} "
          f"breaker open={br['open']} half_open={br['half_open']}")


def _serve_multimodel(args):
    """Heterogeneous-model serving: one route (replica group) per
    requested model behind a single global in-order release stage."""
    servables = [MODELS[m](args) for m in args.model]
    mb = max(8, *(getattr(s.pipe, "microbatch", 1) for s in servables))
    for s in servables:   # warm up compile before traffic
        warm = s.events(mb, 99)
        s.pipe({k: np.stack([e[k] for e in warm]) for k in warm[0]})
    print(f"[serve] deployed design ③{args.design_point} routes="
          f"{[s.name for s in servables]} microbatch={mb}")
    fk = _fault_kwargs(args)
    eng = ShardedTriggerService(
        routes={s.name: s.pipe for s in servables},
        n_replicas=args.replicas, microbatch=mb, window_s=2e-3,
        policy=args.policy, loop=args.loop, **fk)
    per = {s.name: s.events(args.events // len(servables) +
                            (i < args.events % len(servables)),
                            seed=7 + i)
           for i, s in enumerate(servables)}
    t0 = time.perf_counter()
    futs = []
    cursors = {name: iter(evs) for name, evs in per.items()}
    live = list(cursors)
    while live:               # interleave the model streams
        for name in list(live):
            ev = next(cursors[name], None)
            if ev is None:
                live.remove(name)
            else:
                futs.append(eng.submit(ev, route=name))
    results, failed = [], 0
    for f in futs:
        try:
            results.append(f.result(timeout=120))
        except Exception:  # noqa: BLE001 — only under injected chaos
            failed += 1
    dt = time.perf_counter() - t0
    eng.drain()
    released = len(results) + failed
    s = eng.stats.summary()
    print(f"[serve] {released} events in {dt:.2f}s -> "
          f"{released / dt:,.0f} ev/s (CPU, {args.replicas} replica(s) "
          f"per route, {args.policy}, {args.loop} loop)")
    print(f"[serve] latency p50={s['p50_us']:.0f}us "
          f"p99={s['p99_us']:.0f}us batches={s['batches']}")
    route_rows = eng.route_summary()
    for row in route_rows:
        print(f"[serve]   route {row['route']}: "
              f"{row['submitted']} submitted, {row['completed']} "
              f"completed, {row['batches']} batches")
    if fk["faults"] is not None:
        _print_chaos(eng, failed)
    eng.close()
    if args.bench_out:
        bench = {
            "events": args.events, "elapsed_s": dt, "loop": args.loop,
            "throughput_ev_s": released / dt,
            "p50_us": s["p50_us"], "p99_us": s["p99_us"],
            "routes": {row["route"]: {k: v for k, v in row.items()
                                      if k != "route"}
                       for row in route_rows},
            "released_nonzero": released > 0,
        }
        with open(args.bench_out, "w") as f:
            json.dump(bench, f, indent=2)
        print(f"[serve] multi-model stats -> {args.bench_out}")
    if released < args.events:
        raise SystemExit("multi-model serving released fewer events "
                         "than were submitted")
    if fk["faults"] is None and any(
            row["completed"] != row["submitted"] for row in route_rows):
        raise SystemExit("multi-model serving released fewer events "
                         "than were submitted")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", nargs="+", default=["ccn"],
                    choices=sorted(MODELS), metavar="NAME",
                    help="registered model route(s) to serve (default "
                         "ccn). A single 'ccn' runs the full "
                         "demonstrator (training, buckets, "
                         "monitoring); any other selection serves the "
                         "named models side by side through per-route "
                         "replica groups")
    ap.add_argument("--bench-out", default=None, metavar="PATH",
                    help="write per-route serving stats JSON "
                         "(multi-model path only)")
    ap.add_argument("--detector", choices=["current", "upgrade"],
                    default="upgrade")
    ap.add_argument("--design-point", type=int, default=3,
                    choices=[1, 2, 3])
    ap.add_argument("--precision", choices=["fp", "mixed"],
                    default="mixed")
    ap.add_argument("--events", type=int, default=512)
    ap.add_argument("--target-throughput", type=float, default=1e5,
                    help="events/s target for the P-search (CPU scale)")
    ap.add_argument("--tpu-native-gravnet", action="store_true")
    ap.add_argument("--train-steps", type=int, default=40)
    ap.add_argument("--event-display", default=None, metavar="PATH",
                    help="write a JSON event display (shared "
                         "event_display() records, detector-correct "
                         "grid) for the first --event-display-n events")
    ap.add_argument("--event-display-n", type=int, default=16,
                    metavar="N", help="events in the --event-display "
                                      "file (default 16)")
    ap.add_argument("--monitor-port", type=int, default=None,
                    metavar="PORT",
                    help="serve the live monitor over HTTP on this "
                         "port (0 = ephemeral): /snapshot JSON, "
                         "/events NDJSON tail, / HTML/SVG display")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serving replicas (thread-backed on one "
                         "device, device-placed when several exist)")
    ap.add_argument("--loop", choices=["streaming", "deadline"],
                    default="streaming",
                    help="replica hot loop: 'streaming' (default) runs "
                         "the persistent dataflow pipeline — rolling "
                         "batching into preallocated rings, no "
                         "deadline tick; 'deadline' is the escape "
                         "hatch reproducing the original micro-batch "
                         "deadline loop exactly")
    ap.add_argument("--policy", default="round_robin",
                    choices=["round_robin", "least_loaded"])
    ap.add_argument("--inject-faults", default=None, metavar="SPEC",
                    help="deterministic chaos: seeded fault plan, e.g. "
                         "'fail:p=0.05;stall:p=0.02,s=0.01' or "
                         "'fail:p=1.0,replica=1' (dead lane); grammar "
                         "in docs/serving.md. Implies --breaker")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for --inject-faults (bit-identical "
                         "replay)")
    ap.add_argument("--breaker", action="store_true",
                    help="per-replica health tracking + circuit "
                         "breaking (closed/open/half-open)")
    ap.add_argument("--max-retries", type=int, default=0, metavar="N",
                    help="failover: re-dispatch a failed batch's "
                         "events to a healthy sibling up to N times "
                         "before failing to the client")
    ap.add_argument("--shed", action="store_true",
                    help="load shedding: a full replica queue fails "
                         "the event fast with ShedError instead of "
                         "blocking submit()")
    ap.add_argument("--buckets", type=int, nargs="+", default=None,
                    metavar="N_HITS",
                    help="occupancy buckets (e.g. 8 16 32): deploy one "
                         "batch-packed executable per bucket and "
                         "dispatch each event to the smallest bucket "
                         "that fits its non-zero hit count")
    ap.add_argument("--bucket-microbatch", type=int, default=8,
                    metavar="B",
                    help="micro-batch width each bucket executable "
                         "packs per launch (default 8)")
    ap.add_argument("--tuning-cache", default=None, metavar="PATH",
                    help="JSON kernel-tuning cache consulted when "
                         "binding kernels and warming replicas "
                         "(absent/corrupt -> heuristic defaults)")
    ap.add_argument("--tune", action="store_true",
                    help="autotune this deployment's kernel shapes "
                         "before serving; winners are persisted to "
                         "--tuning-cache when given")
    ap.add_argument("--no-fuse-gravnet-block", action="store_true",
                    help="escape hatch: keep the unfused dense→"
                         "aggregate→dense GravNet chains (legacy "
                         "graphs and tuning-cache keys, bit-for-bit) "
                         "instead of the fused megakernel")
    ap.add_argument("--no-fuse-int8", action="store_true",
                    help="int8-specific escape hatch: under "
                         "--precision mixed, keep the legacy unfused "
                         "calibrated int8 dense chain (and its tuning "
                         "keys, bit-for-bit) instead of the quantized "
                         "megakernel; fp deployments still fuse")
    args = ap.parse_args()

    if args.model != ["ccn"]:
        return _serve_multimodel(args)

    from repro.core import caloclusternet as ccn
    from repro.data.belle2 import (Belle2Config, current_detector,
                                   generate)
    if args.detector == "current":
        cfg = ccn.current_detector_config()
        gen_cfg = current_detector()
    else:
        cfg = ccn.CCNConfig()
        gen_cfg = Belle2Config()

    params = ccn.init(jax.random.PRNGKey(0), cfg)
    if args.train_steps > 0:   # brief condensation training so the
        import jax.numpy as jnp    # demo's decisions are meaningful
        from repro.core.condensation import condensation_loss
        from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                                 cosine_warmup)
        ocfg = AdamWConfig(weight_decay=0.01)
        lrf = cosine_warmup(peak_lr=2e-3, warmup_steps=10,
                            total_steps=args.train_steps)
        opt = adamw_init(params, ocfg)

        @jax.jit
        def _step(p, o, b):
            def lf(q):
                out = ccn.apply(q, b["feats"], b["mask"], cfg)
                labels = {"object_id": b["object_id"],
                          "energy": b["energy"], "cls": b["cls"]}
                return condensation_loss(out, labels, b["mask"],
                                         k_max=cfg.k_max)
            (l, m), g = jax.value_and_grad(lf, has_aux=True)(p)
            p2, o2, _ = adamw_update(g, o, p, lr=lrf(o["step"]), cfg=ocfg)
            return p2, o2, l

        for st in range(args.train_steps):
            raw = generate(gen_cfg, 32, seed=500 + st)
            b = {k: jnp.asarray(v) for k, v in raw.items()
                 if k != "trigger_truth"}
            params, opt, l = _step(params, opt, b)
        print(f"[serve] warm-trained {args.train_steps} steps, "
              f"loss {float(l):.3f}")
    graph = export_graph("caloclusternet", params, cfg)
    calib = generate(gen_cfg, 64, seed=123)
    feeds = {"hits": calib["feats"], "mask": calib["mask"]}
    req = Requirements(design_point=args.design_point, platform="cpu",
                       precision_policy=args.precision,
                       n_hits=cfg.n_hits,
                       target_throughput=args.target_throughput,
                       max_latency_s=2e-3,
                       tpu_native_gravnet=args.tpu_native_gravnet)
    cache = None
    if args.tuning_cache or args.tune:
        from repro.tuning import TuningCache
        cache = TuningCache.load(args.tuning_cache) if args.tuning_cache \
            else TuningCache()
        if cache.load_error:
            print(f"[serve] WARNING: {cache.load_error}; "
                  "falling back to heuristic kernel defaults")
    monitoring = args.monitor_port is not None or args.event_display
    monitor_cfg = {"detector": gen_cfg,
                   "display_n": max(args.event_display_n, 64)} \
        if monitoring else False
    fuse_block = not args.no_fuse_gravnet_block
    fuse_int8 = not args.no_fuse_int8
    fk = _fault_kwargs(args)
    if args.buckets:
        mb = args.bucket_microbatch
        bpipe = deploy_bucketed(graph, req, buckets=args.buckets,
                                microbatch=mb, calibration_feeds=feeds,
                                tuning_cache=cache,
                                fuse_gravnet_block=fuse_block,
                                fuse_int8=fuse_int8)
        if args.tune:
            fresh = _tune_and_rebind(
                cache, args,
                [(p.graph, b, mb, p.backend)
                 for b, p in bpipe.pipes.items()],
                lambda: deploy_bucketed(
                    graph, req, buckets=args.buckets, microbatch=mb,
                    calibration_feeds=feeds, tuning_cache=cache,
                    fuse_gravnet_block=fuse_block, fuse_int8=fuse_int8))
            if fresh is not None:
                bpipe = fresh
        print(f"[serve] deployed design ③{args.design_point} "
              f"buckets={bpipe.buckets} microbatch={mb} "
              f"(one batch-packed executable per bucket)")
        eng = ShardedTriggerService(
            buckets=bpipe, n_replicas=args.replicas, microbatch=mb,
            window_s=2e-3, hedge_after_s=None, policy=args.policy,
            monitor=monitor_cfg, loop=args.loop, **fk)
        print(f"[serve] bucket executables pre-compiled at startup: "
              f"{sum(r.warmed for r in eng.replicas)}")
    else:
        pipe = deploy(graph, req, calibration_feeds=feeds,
                      tuning_cache=cache, fuse_gravnet_block=fuse_block,
                      fuse_int8=fuse_int8)
        if args.tune:
            fresh = _tune_and_rebind(
                cache, args, [(pipe.graph, cfg.n_hits, 1, pipe.backend)],
                lambda: deploy(graph, req, calibration_feeds=feeds,
                               tuning_cache=cache,
                               fuse_gravnet_block=fuse_block,
                               fuse_int8=fuse_int8))
            if fresh is not None:
                pipe = fresh
        print(f"[serve] deployed design ③{args.design_point} "
              f"segments={len(pipe.segments)} P={pipe.par}")

        def infer(batch):
            return pipe({"hits": batch["hits"], "mask": batch["mask"]})

        # warmup compile
        warm = {"hits": calib["feats"][:pipe.microbatch],
                "mask": calib["mask"][:pipe.microbatch]}
        infer(warm)

        warmup_fn = None
        if cache is not None and len(cache):
            from repro.tuning import make_warmup
            warmup_fn = make_warmup(cache, backend=pipe.backend)
        eng = ShardedTriggerService(
            infer, n_replicas=args.replicas,
            microbatch=max(pipe.microbatch, 16), window_s=2e-3,
            hedge_after_s=None, policy=args.policy, warmup_fn=warmup_fn,
            monitor=monitor_cfg, loop=args.loop, **fk)
        if warmup_fn is not None:
            print(f"[serve] replicas warmed "
                  f"{sum(r.warmed for r in eng.replicas)} cached kernel "
                  f"shape(s) at startup")
    server = None
    if args.monitor_port is not None:
        server = MonitorServer.for_service(eng, port=args.monitor_port)
        print(f"[serve] monitor live at {server.url} "
              f"(/snapshot, /events, / = event display)")
    events = generate(gen_cfg, args.events, seed=7)
    truth = events["trigger_truth"] > 0
    t0 = time.perf_counter()
    futs = []
    for i in range(args.events):
        futs.append(eng.submit({"hits": events["feats"][i],
                                "mask": events["mask"][i]},
                               truth=bool(truth[i]) if monitoring
                               else None))
    results, failed = [], 0
    for f in futs:
        try:
            results.append(f.result(timeout=120))
        except Exception:  # noqa: BLE001 — only under injected chaos
            results.append(None)
            failed += 1
    dt = time.perf_counter() - t0
    eng.drain()
    s = eng.stats.summary()
    trig = np.asarray([bool(r["cps"]["trigger"]) if r is not None
                       else False for r in results])
    eff = float((trig & truth).sum() / max(truth.sum(), 1))
    fake = float((trig & ~truth).sum() / max((~truth).sum(), 1))
    print(f"[serve] {args.events} events in {dt:.2f}s -> "
          f"{args.events / dt:,.0f} ev/s (CPU, "
          f"{args.replicas} replica(s), {args.policy}, "
          f"{args.loop} loop)")
    print(f"[serve] latency p50={s['p50_us']:.0f}us "
          f"p99={s['p99_us']:.0f}us batches={s['batches']}")
    bud = s["budget"]
    print(f"[serve] budget queue_wait={bud['queue_wait_us_mean']:.0f}us "
          f"dispatch={bud['dispatch_us_mean']:.0f}us "
          f"compute={bud['compute_us_mean']:.0f}us")
    for rs in s["per_replica"]:
        print(f"[serve]   replica {rs['replica_id']}: "
              f"{rs['completed']} events, {rs['batches']} batches, "
              f"{rs['throughput_ev_s']:,.0f} ev/s")
    if args.buckets:
        for bs in eng.bucket_summary():
            print(f"[serve]   bucket n_hits<={bs['bucket']}: "
                  f"{bs['submitted']} events, {bs['batches']} batches, "
                  f"{bs['padded_events']} padded")
    print(f"[serve] trigger efficiency={eff:.3f} fake rate={fake:.3f} "
          f"in-order=True")
    if fk["faults"] is not None:
        _print_chaos(eng, failed)
    if monitoring:
        snap = eng.monitor_snapshot()

        def f3(x):      # snapshot stats are None when undefined (e.g.
            return "n/a" if x is None else f"{x:.3f}"   # one-class truth)

        print(f"[serve] monitor: {snap['events']} events, "
              f"trigger_rate={f3(snap['trigger_rate'])}, "
              f"efficiency={f3(snap['efficiency'])}, "
              f"fake_rate={f3(snap['fake_rate'])}, "
              f"rate={snap['rate_ev_s']:,.0f} ev/s (windowed)")
    if server is not None:
        # prove the live endpoint agrees with the engine's own stats
        live = json.load(urllib.request.urlopen(
            f"{server.url}/snapshot", timeout=10))
        ok = live["events"] == s["completed"]
        print(f"[serve] /snapshot events={live['events']} vs "
              f"stats completed={s['completed']} -> "
              f"{'MATCH' if ok else 'MISMATCH'}")
        if not ok:
            raise SystemExit("monitor snapshot disagrees with "
                             "serving stats")
    if args.event_display:
        disp = [event_display(r["cps"], event_id=i, detector=gen_cfg,
                              truth=bool(truth[i]))
                for i, r in enumerate(results[:args.event_display_n])
                if r is not None]
        write_display(args.event_display, disp)
        print(f"[serve] event display ({len(disp)} events) -> "
              f"{args.event_display}")
    if server is not None:
        server.close()
    eng.close()


if __name__ == "__main__":
    main()
