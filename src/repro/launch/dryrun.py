import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (jax locks
# the device count at first init). Only the dry-run sees 512 placeholder
# host devices; tests/benchmarks keep the single real CPU device.

import argparse        # noqa: E402
import json            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402

from repro import configs                                   # noqa: E402
from repro.launch import analysis                           # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")


def _mem_dict(ma):
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "temp_size_in_bytes",
            "alias_size_in_bytes")
    return {k: int(getattr(ma, k, 0)) for k in keys}


def run_cell(arch: str, shape: str, *, multi_pod: bool, cost_pass: bool,
             report_dir: str, force: bool = False) -> dict:
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    out_path = os.path.join(report_dir, f"{arch}__{shape}__{mesh_tag}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    mod = configs.get_arch(arch)
    cell = mod.cell(shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    lowered = cell.lower(mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    terms = analysis.cost_terms(compiled)
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_tag,
        "kind": cell.kind, "n_chips": n_chips,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "memory": _mem_dict(ma),
        "per_device": {k: terms[k] for k in
                       ("flops", "bytes", "collective_bytes")},
        "collectives": terms["collectives"],
        "model_flops": cell.model_flops,
    }
    print(f"[dryrun] {arch}:{shape} @{mesh_tag}  "
          f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
    print(f"  memory_analysis: {ma}")
    print(f"  cost_analysis: flops={terms['flops']:.3e} "
          f"bytes={terms['bytes']:.3e} "
          f"coll={terms['collective_bytes']:.3e}")

    # LM archs: scan-corrected cost composition (single-pod only)
    if cost_pass and mod.FAMILY == "lm" and not multi_pod:
        from repro.configs import lm_common
        quant = arch.startswith("llama4")
        ccells, l_full = lm_common.cost_cells(
            arch, mod.full_config(), shape, quantize_opt=quant)
        sub = {}
        for lred, c2 in ccells.items():
            t0 = time.time()
            comp2 = c2.lower(mesh).compile()
            sub[lred] = analysis.cost_terms(comp2)
            print(f"  cost-variant L={lred}: flops="
                  f"{sub[lred]['flops']:.3e} ({time.time()-t0:.1f}s)")
        corrected = analysis.affine_extrapolate(sub[2], sub[4], l_full)
        rec["per_device_corrected"] = corrected
        rec["cost_variants"] = {str(k): {kk: v[kk] for kk in
                                         ("flops", "bytes",
                                          "collective_bytes")}
                                for k, v in sub.items()}

    effective = rec.get("per_device_corrected", rec["per_device"])
    rec["roofline"] = analysis.roofline(effective, n_chips=n_chips,
                                        model_flops=cell.model_flops)
    os.makedirs(report_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--no-cost-pass", action="store_true")
    ap.add_argument("--include-paper", action="store_true",
                    help="also dry-run caloclusternet cells")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--report-dir", default=os.path.normpath(REPORT_DIR))
    args = ap.parse_args()

    assert jax.device_count() == 512, \
        f"expected 512 host devices, got {jax.device_count()}"

    cells = []
    for arch, shape, mod in configs.all_cells(
            include_paper=args.include_paper):
        if args.arch and arch != args.arch:
            continue
        if args.shape and shape != args.shape:
            continue
        cells.append((arch, shape))

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    for arch, shape in cells:
        for multi in meshes:
            try:
                run_cell(arch, shape, multi_pod=multi,
                         cost_pass=not args.no_cost_pass,
                         report_dir=args.report_dir, force=args.force)
            except Exception as e:  # keep going, report at end
                failures.append((arch, shape, multi, repr(e)))
                traceback.print_exc()
    print(f"\n[dryrun] {len(cells) * len(meshes) - len(failures)} ok, "
          f"{len(failures)} failed")
    for f_ in failures:
        print("  FAILED:", f_)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
