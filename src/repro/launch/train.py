"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Production loop structure (single-host CPU execution of the same code
that the pod mesh runs — the step function comes from the arch's Cell):

  data Prefetcher (seeded, resume-exact) →
  jitted train step →
  CheckpointManager (async, atomic, rotating) →
  supervision loop with failure injection + restore-and-resume
  (elastic: restore re-shards to whatever mesh is alive).

For the paper's own architecture (caloclusternet) this trains the object-
condensation loss on the synthetic Belle II generator.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import Prefetcher


def make_data_stream(arch: str, mod, smoke_cfg, batch: int, seed: int,
                     start_step: int):
    if mod.FAMILY == "lm":
        from repro.data.lm import lm_stream
        return lm_stream(smoke_cfg.vocab, batch, 64, seed=seed,
                         start_step=start_step)
    if mod.FAMILY == "recsys":
        from repro.data.recsys import mind_stream
        return mind_stream(smoke_cfg, batch, seed=seed,
                           start_step=start_step)
    if mod.FAMILY == "trigger":
        from repro.data.belle2 import Belle2Config, event_stream
        gen = Belle2Config(n_crystals=576, grid=(24, 24),
                           n_hits=smoke_cfg.n_hits, noise_rate=4.0)
        return event_stream(gen, batch, seed0=seed + start_step)
    raise ValueError(f"no generic stream for family {mod.FAMILY}; "
                     "use examples/ drivers for GNN archs")


def build_step(arch: str, mod, cfg):
    """Reduced-scale train step mirroring the Cell's step."""
    from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                             cosine_warmup)
    ocfg = AdamWConfig()
    lr = cosine_warmup(peak_lr=3e-4, warmup_steps=20, total_steps=2000)

    if mod.FAMILY == "lm":
        from repro.models import transformer as tr

        def loss_fn(p, b):
            return tr.loss_fn(p, b, cfg, None)

        init_params = lambda key: tr.init_params(key, cfg)  # noqa: E731

        def to_batch(raw):
            return {"tokens": jnp.asarray(raw["tokens"]),
                    "labels": jnp.asarray(raw["labels"])}
    elif mod.FAMILY == "recsys":
        from repro.models import recsys as rec

        def loss_fn(p, b):
            return rec.loss_fn(p, b, cfg)

        init_params = lambda key: rec.init(key, cfg)  # noqa: E731

        def to_batch(raw):
            return {k: jnp.asarray(v) for k, v in raw.items()}
    elif mod.FAMILY == "trigger":
        from repro.core import caloclusternet as ccn
        from repro.core.condensation import condensation_loss

        def loss_fn(p, b):
            out = ccn.apply(p, b["feats"], b["mask"], cfg)
            labels = {"object_id": b["object_id"], "energy": b["energy"],
                      "cls": b["cls"]}
            return condensation_loss(out, labels, b["mask"],
                                     k_max=cfg.k_max)

        init_params = lambda key: ccn.init(key, cfg)  # noqa: E731

        def to_batch(raw):
            return {k: jnp.asarray(v) for k, v in raw.items()
                    if k != "trigger_truth"}
    else:
        raise ValueError(mod.FAMILY)

    @jax.jit
    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        new_p, new_s, aux = adamw_update(grads, opt_state, params,
                                         lr=lr(opt_state["step"]),
                                         cfg=ocfg)
        return new_p, new_s, {**metrics, **aux, "loss": loss}

    return step, init_params, to_batch, ocfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failure-at", type=int, default=None,
                    help="simulate a node failure at this step "
                         "(exercises restore-and-resume)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    mod = configs.get_arch(args.arch)
    cfg = mod.smoke_config()
    step, init_params, to_batch, ocfg = build_step(args.arch, mod, cfg)
    from repro.optim import adamw_init

    mgr = CheckpointManager(args.ckpt_dir, keep=3, async_=True)
    params = init_params(jax.random.PRNGKey(args.seed))
    opt = adamw_init(params, ocfg)
    start = 0
    if mgr.latest() is not None:
        restored, rstep = mgr.restore_latest({"p": params, "o": opt})
        params, opt = restored["p"], restored["o"]
        start = rstep
        print(f"[train] resumed from step {start}")

    stream = make_data_stream(args.arch, mod, cfg, args.batch, args.seed,
                              start)
    injected = False
    t0 = time.time()
    with Prefetcher(stream, depth=2) as pf:
        s = start
        while s < args.steps:
            if (args.inject_failure_at is not None and not injected
                    and s == args.inject_failure_at):
                injected = True
                print(f"[train] >>> injected node failure at step {s}; "
                      "restoring from last checkpoint")
                mgr.wait()
                rstep = mgr.latest()
                if rstep is None:
                    print("[train] no checkpoint yet; restarting step")
                if rstep is not None:
                    restored, s = mgr.restore_latest(
                        {"p": params, "o": opt})
                    params, opt = restored["p"], restored["o"]
                    stream = make_data_stream(args.arch, mod, cfg,
                                              args.batch, args.seed, s)
                    pf.close()
                    pf = Prefetcher(stream, depth=2)
                continue
            batch = to_batch(pf.get())
            params, opt, metrics = step(params, opt, batch)
            s += 1
            if s % args.log_every == 0:
                loss = float(metrics.get("loss", jnp.nan))
                rate = (s - start) / (time.time() - t0)
                print(f"[train] step {s} loss {loss:.4f} "
                      f"({rate:.1f} steps/s, "
                      f"stragglers={pf.stats['stragglers']})")
            if s % args.ckpt_every == 0:
                mgr.save(s, {"p": params, "o": opt})
    mgr.wait()
    print(f"[train] done at step {s}; final loss "
          f"{float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
