import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Perf hillclimbing (EXPERIMENTS.md §Perf): hypothesis → change →
# re-lower → re-analyse, on the three selected cells. Must run in its own
# process (512 placeholder devices), like dryrun.py.

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402

from repro import configs                                  # noqa: E402
from repro.launch import analysis                          # noqa: E402
from repro.launch.dryrun import _mem_dict                  # noqa: E402
from repro.launch.mesh import make_production_mesh         # noqa: E402

REPORT_DIR = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "reports", "hillclimb"))


def measure(cell, *, cost_cells=None, l_full=None):
    mesh = make_production_mesh()
    t0 = time.time()
    compiled = cell.lower(mesh).compile()
    terms = analysis.cost_terms(compiled)
    rec = {"memory": _mem_dict(compiled.memory_analysis()),
           "per_device": {k: terms[k] for k in
                          ("flops", "bytes", "collective_bytes")},
           "collectives": terms["collectives"]["counts"],
           "t_compile_s": round(time.time() - t0, 1)}
    if cost_cells is not None:
        sub = {}
        for lred, c2 in cost_cells.items():
            comp2 = c2.lower(mesh).compile()
            sub[lred] = analysis.cost_terms(comp2)
        rec["per_device_corrected"] = analysis.affine_extrapolate(
            sub[2], sub[4], l_full)
    eff = rec.get("per_device_corrected", rec["per_device"])
    rec["roofline"] = analysis.roofline(eff, n_chips=mesh.devices.size,
                                        model_flops=cell.model_flops)
    return rec


def report(tag, hypothesis, rec, baseline=None):
    rf = rec["roofline"]
    mem_gib = (rec["memory"]["argument_size_in_bytes"]
               + rec["memory"]["temp_size_in_bytes"]
               + rec["memory"]["output_size_in_bytes"]) / 2 ** 30
    line = (f"[{tag}] C={rf['t_compute_s'] * 1e3:.3f}ms "
            f"M={rf['t_memory_s'] * 1e3:.3f}ms "
            f"X={rf['t_collective_s'] * 1e3:.3f}ms "
            f"dom={rf['dominant']} mem={mem_gib:.2f}GiB "
            f"useful={rf['useful_flops_ratio']:.2f}")
    if baseline is not None:
        b = baseline["roofline"]
        st_b = max(b["t_compute_s"], b["t_memory_s"], b["t_collective_s"])
        st_n = max(rf["t_compute_s"], rf["t_memory_s"],
                   rf["t_collective_s"])
        line += f"  step {st_b * 1e3:.2f}->{st_n * 1e3:.2f}ms " \
                f"({st_b / max(st_n, 1e-12):.1f}x)"
    print(line)
    os.makedirs(REPORT_DIR, exist_ok=True)
    rec["hypothesis"] = hypothesis
    with open(os.path.join(REPORT_DIR, f"{tag}.json"), "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


# ------------------------------------------------------------ experiments ----
def exp_decode(run_also_kv8=True):
    """Cell A: yi-9b:decode_32k (most collective-bound)."""
    from repro.configs import lm_common
    mod = configs.get_arch("yi-9b")
    cfg = mod.full_config()

    base = json.load(open("reports/dryrun/yi-9b__decode_32k__pod16x16"
                          ".json"))
    base_rec = {"roofline": base["roofline"], "memory": base["memory"]}
    print("[A0 baseline] dom=", base["roofline"]["dominant"],
          " X=", base["roofline"]["t_collective_s"])

    # A1: serving shardings (TP-only params; no per-step FSDP gathers)
    hyp = ("FSDP all-gathers 9B bf16 params every decode step "
           "(18GB/16 per device over ICI ≈ 1.1GB/50GBps ≈ 22ms·48L-ish); "
           "TP-only inference layout removes them; predict X drops "
           ">50x, memory (params 1.1GB + cache 1.6GB reads) dominates")
    cell = lm_common.decode_cell("yi-9b", cfg, "decode_32k",
                                 serving_shardings=True)
    cc, lf = lm_common.cost_cells("yi-9b", cfg, "decode_32k",
                                  serving_shardings=True)
    a1 = report("A1_yi9b_decode_serving_tp", hyp,
                measure(cell, cost_cells=cc, l_full=lf), base_rec)

    if not run_also_kv8:
        return
    # A2: + int8 KV cache with per-token scales
    hyp2 = ("memory term now dominated by KV-cache reads "
            "(412GB global bf16 / 256 dev = 1.6GB/dev @819GBps ≈ 2ms); "
            "int8 cache halves that; predict M -> ~0.65x")
    cfg8 = dataclasses.replace(cfg, kv_cache_int8=True)
    cell = lm_common.decode_cell("yi-9b", cfg8, "decode_32k",
                                 serving_shardings=True)
    cc, lf = lm_common.cost_cells("yi-9b", cfg8, "decode_32k",
                                  serving_shardings=True)
    report("A2_yi9b_decode_serving_tp_kv8", hyp2,
           measure(cell, cost_cells=cc, l_full=lf), a1)


def exp_train():
    """Cell B: granite-34b:train_4k (worst roofline; OOM at baseline)."""
    from repro.configs import lm_common
    mod = configs.get_arch("granite-34b")
    cfg = mod.full_config()
    base = json.load(open("reports/dryrun/granite-34b__train_4k__"
                          "pod16x16.json"))
    base_rec = {"roofline": base["roofline"], "memory": base["memory"]}

    # B1: sequence-parallel residual stream
    hyp = ("baseline stores the (B/dp,S,D) residual per layer replicated "
           "over tp: 88·805MB ≈ 70GB/dev -> OOM; sharding the seq dim "
           "over tp=16 between blocks cuts activation memory and bytes "
           "~16x on the residual path; predict temp 203GB -> ~16GB and "
           "memory term -4x+")
    cfg1 = dataclasses.replace(cfg, seq_parallel=True)
    cell = lm_common.train_cell("granite-34b", cfg1)
    cc, lf = lm_common.cost_cells("granite-34b", cfg1, "train_4k")
    b1 = report("B1_granite34b_train_seqpar", hyp,
                measure(cell, cost_cells=cc, l_full=lf), base_rec)

    # B2: + gradient accumulation (4 microbatches)
    hyp2 = ("remaining activations scale with microbatch; ga=4 cuts live "
            "batch 4x at the cost of 4 sequential scans (same FLOPs); "
            "predict temp -> /3-4, bytes roughly flat")
    cell = lm_common.train_cell("granite-34b", cfg1, grad_accum=4)
    cc, lf = lm_common.cost_cells("granite-34b", cfg1, "train_4k",
                                  grad_accum=4)
    b2 = report("B2_granite34b_train_seqpar_ga4", hyp2,
                measure(cell, cost_cells=cc, l_full=lf), b1)

    # B3: + bf16 params in the step (cast once, halve weight traffic)
    hyp3 = ("with activations sharded, per-device bytes are dominated by "
            "fp32 master params + optimizer state traffic (34B·12B/256 "
            "≈ 1.6GB) and weight reads each layer; int8 optimizer "
            "moments halve optimizer traffic; predict bytes -15-25%")
    cell = lm_common.train_cell("granite-34b", cfg1, grad_accum=4,
                                quantize_opt=True)
    cc, lf = lm_common.cost_cells("granite-34b", cfg1, "train_4k",
                                  grad_accum=4, quantize_opt=True)
    report("B3_granite34b_train_seqpar_ga4_q8opt", hyp3,
           measure(cell, cost_cells=cc, l_full=lf), b2)


def exp_trigger():
    """Cell C: caloclusternet:trigger_serve (paper-representative)."""
    import repro.configs.caloclusternet as ccncfg
    base = json.load(open("reports/dryrun/caloclusternet__trigger_serve"
                          "__pod16x16.json"))
    base_rec = {"roofline": base["roofline"], "memory": base["memory"]}

    # C1: bf16 serving activations
    hyp = ("trigger serving is bytes-bound (tiny matrices, N=128 "
           "events·hits streams); bf16 activations halve activation "
           "traffic; predict M -> ~0.5-0.6x")
    cell = _ccn_variant(ccncfg, compute_dtype="bf16")
    c1 = report("C1_ccn_serve_bf16", hyp, measure(cell), base_rec)

    # C2: + MXU-native gravnet (one-hot matmul instead of top_k+gather)
    hyp2 = ("top_k+gather lowers to sort+scatter (VPU/memory-heavy, and "
            "the collectives around the gathers dominate X); the "
            "argmin/one-hot-matmul form is dense MXU work with static "
            "schedules; predict X and M both drop, C rises slightly")
    cell = _ccn_variant(ccncfg, compute_dtype="bf16",
                        gravnet_impl="onehot")
    report("C2_ccn_serve_bf16_onehot", hyp2, measure(cell), c1)


def _ccn_variant(ccncfg, **over):
    import dataclasses as dc
    cfg = dc.replace(ccncfg.full_config("upgrade"), **over)
    return ccncfg._serve_cell(cfg, "trigger_serve", 4096)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", choices=["decode", "train", "trigger", "all"],
                    default="all")
    args = ap.parse_args()
    if args.exp in ("decode", "all"):
        exp_decode()
    if args.exp in ("train", "all"):
        exp_train()
    if args.exp in ("trigger", "all"):
        exp_trigger()


if __name__ == "__main__":
    main()
