"""Compiled-artifact analysis: cost terms, collective bytes, roofline.

Sources (EXPERIMENTS.md §Roofline):
- ``compiled.cost_analysis()``  -> HLO FLOPs + bytes accessed
- ``compiled.as_text()``        -> post-SPMD HLO; collective bytes are the
  summed output sizes of all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute ops (per-device shapes after
  partitioning).

Scan caveat (measured, see EXPERIMENTS.md §Methodology): XLA cost analysis
counts a while/scan body ONCE. Architectures whose layer loop is a python
loop (all GNNs, MIND, CaloClusterNet) are exact. LM archs lower scan-free
cost variants at n_layers ∈ {2,4}; F(L) is affine in L, so
F_full = F(2) + (F(4)-F(2))/2 · (L-2). The same composition applies to
bytes and collective bytes.
"""
from __future__ import annotations

import re

from repro.launch import mesh as hw

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> float:
    """'bf16[8,128]' -> bytes; handles tuple results '(f32[2], s32[2])'."""
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum output bytes per collective kind from post-SPMD HLO text."""
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # '%x = bf16[..]{..} all-gather(' / ' ROOT %y = (f32[..]) all-reduce('
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        op = m.group(2)
        # strip -start/-done fusion suffixes (async collectives)
        base = op.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES:
            if op.endswith("-done"):
                continue  # counted at -start
            out[base] += _shape_bytes(m.group(1))
            counts[base] += 1
    out["total_bytes"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


def cost_terms(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # some backends return [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    colls = parse_collectives(compiled.as_text())
    return {"flops": flops, "bytes": byts,
            "collective_bytes": colls["total_bytes"],
            "collectives": colls}


def affine_extrapolate(t2: dict, t4: dict, l_full: int) -> dict:
    """F(L) = a + b·L from L=2, L=4 measurements."""
    out = {}
    for k in ("flops", "bytes", "collective_bytes"):
        b = (t4[k] - t2[k]) / 2.0
        a = t2[k] - 2.0 * b
        out[k] = a + b * l_full
    return out


def roofline(terms: dict, *, n_chips: int, model_flops: float) -> dict:
    """Three-term roofline (seconds) + dominant bottleneck.

    FLOPs/bytes from cost_analysis are whole-program totals of the SPMD
    module (per-device work × … XLA reports the module as lowered — on
    the CPU backend the SPMD module is per-device, so divide by nothing;
    totals here treat cost_analysis as PER-DEVICE work and multiply terms
    accordingly — see EXPERIMENTS.md §Methodology for validation).
    """
    t_compute = terms["flops"] / hw.PEAK_FLOPS_BF16
    t_memory = terms["bytes"] / hw.HBM_BW
    t_coll = terms["collective_bytes"] / hw.ICI_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_coll)), key=lambda kv: kv[1])[0]
    step_time = max(t_compute, t_memory, t_coll)
    useful = model_flops / max(terms["flops"] * n_chips, 1.0)
    mfu = (model_flops / n_chips / max(step_time, 1e-12)
           ) / hw.PEAK_FLOPS_BF16
    return {
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "step_time_s": step_time,
        "model_flops": model_flops,
        "useful_flops_ratio": useful,
        "roofline_fraction_mfu": mfu,
    }
