"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (never evaluated at import) so that
importing this module does not touch jax device state — smoke tests and
benchmarks must keep seeing the single real CPU device; only the dry-run
sets XLA_FLAGS for 512 placeholder host devices before first jax init.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline model and the
# parallelization pass's throughput estimator.
PEAK_FLOPS_BF16 = 197e12      # per chip, FLOP/s
PEAK_FLOPS_INT8 = 394e12      # per chip, OP/s (int8 MXU)
HBM_BW = 819e9                # per chip, B/s
ICI_BW = 50e9                 # per link, B/s
VMEM_BYTES = 128 * 1024 * 1024


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for smoke tests / CPU benchmarks."""
    return jax.make_mesh((1, 1), ("data", "model"))


def replica_devices(n_replicas: int):
    """Device placement for the sharded serving layer.

    With multiple local devices, replica i is pinned to device
    ``i % n_devices`` (its feeds are moved there with
    ``jax.device_put`` before dispatch).  With a single device the
    replicas are thread-backed and share it: placement is a no-op, so
    every entry is ``None``.
    """
    devs = jax.local_devices()
    if len(devs) <= 1:
        return [None] * n_replicas
    return [devs[i % len(devs)] for i in range(n_replicas)]
