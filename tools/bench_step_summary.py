"""Render the benchmark results as a GitHub step-summary table.

Reads ``BENCH_summary.json`` (the consolidated per-section scoreboard
``benchmarks/run.py`` writes) plus the standalone ``BENCH_*.json``
trajectory files the CI bench-smoke job produces, and prints a
markdown score table to stdout.  The CI workflow pipes it into
``$GITHUB_STEP_SUMMARY`` with ``if: always()``, so a red gate still
shows *which* number missed:

    python tools/bench_step_summary.py >> "$GITHUB_STEP_SUMMARY"

Everything here is defensive — a missing or reshaped file yields a
skipped row, never a crashed summary step.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _load(name: str) -> dict | list | None:
    path = REPO / name
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def _fmt(x) -> str:
    if x is None:
        return "—"
    if isinstance(x, bool):
        return "✅" if x else "❌"
    if isinstance(x, float):
        return f"{x:,.2f}"
    return str(x)


def section_table(summary: dict) -> list[str]:
    lines = [f"### Benchmark sections "
             f"(`{summary.get('git_sha', '?')}`)", "",
             "| section | ok | score | seconds |",
             "|---|---|---|---|"]
    sections = summary.get("sections")
    if not isinstance(sections, dict):
        return []
    for name, entry in sections.items():
        if not isinstance(entry, dict):
            continue
        lines.append(f"| {name} | {_fmt(entry.get('ok'))} "
                     f"| {_fmt(entry.get('score'))} "
                     f"| {_fmt(entry.get('seconds'))} |")
    return lines


# headline extractors per standalone trajectory file: each returns a
# list of (metric, value) rows, or raises — callers swallow the error
# and skip the file.
def _latency_rows(d: dict) -> list[tuple[str, object]]:
    s = d["loops"]["streaming"]
    dl = d["loops"]["deadline"]
    return [
        ("streaming p50 / p99 (µs)",
         f"{s['p50_us']:,.0f} / {s['p99_us']:,.0f}"),
        ("deadline p50 / p99 (µs)",
         f"{dl['p50_us']:,.0f} / {dl['p99_us']:,.0f}"),
        ("p99 ratio (gate ≤ %.2f)" % d["check"]["max_p99_ratio"],
         f"{d['p99_ratio_streaming_vs_deadline']:.2f}"),
        ("SLO gate", bool(d["check"]["pass"])),
    ]


def _batching_rows(d: list) -> list[tuple[str, object]]:
    best = max(p["speedup"] for p in d if p.get("microbatch", 0) >= 8)
    return [("best batch-packing speedup (mb ≥ 8)", f"{best:.2f}×")]


def _fusion_rows(d: list) -> list[tuple[str, object]]:
    worst = min(min(p["block_speedup"], p["int8_speedup"])
                for p in d if p.get("microbatch", 0) >= 8)
    return [("worst fused-block speedup (mb ≥ 8)", f"{worst:.2f}×")]


def _ragged_rows(d: dict) -> list[tuple[str, object]]:
    return [
        ("bucketed / ragged (ev/s)",
         f"{d['bucketed_ev_s']:,.0f} / {d['ragged_ev_s']:,.0f}"),
        ("ragged speedup (gate ≥ %.2f×)" % d["min_speedup"],
         f"{d['speedup']:.2f}×"),
        ("ragged gate", bool(d["speedup"] >= d["min_speedup"])),
    ]


def _monitoring_rows(d: dict) -> list[tuple[str, object]]:
    return [("monitoring hot-path overhead",
             f"{100 * d['overhead_frac']:.2f}%")]


def _faults_rows(d: dict) -> list[tuple[str, object]]:
    deg = d["degradation"]
    tot = d["totals"]
    chk = d["check"]
    return [
        ("healthy / one-dead ok-throughput (ev/s)",
         f"{deg['healthy_ok_ev_s']:,.0f} / {deg['one_dead_ok_ev_s']:,.0f}"),
        ("degradation ratio (gate ≥ %.2f)" % chk["min_dead_ratio"],
         f"{deg['ratio']:.2f}"),
        ("shed / retried / failed-over",
         f"{tot['shed']} / {tot['retried']} / {tot['failed_over']}"),
        ("breaker trips", str(tot["breaker_trips"])),
        ("exactly-once", bool(chk["exactly_once"])),
        ("chaos gate", bool(chk["pass"])),
    ]


def _multimodel_rows(d: dict) -> list[tuple[str, object]]:
    rows: list[tuple[str, object]] = [
        (f"route {name}: completed / batches",
         f"{r['completed']} / {r['batches']}")
        for name, r in d["routes"].items()]
    rows.append(("multi-model throughput (ev/s)",
                 f"{d['throughput_ev_s']:,.0f}"))
    rows.append(("all submitted events released",
                 bool(d["released_nonzero"])))
    return rows


_HEADLINES = {
    "BENCH_latency.json": _latency_rows,
    "BENCH_batching.json": _batching_rows,
    "BENCH_ragged.json": _ragged_rows,
    "BENCH_fusion.json": _fusion_rows,
    "BENCH_monitoring.json": _monitoring_rows,
    "BENCH_multimodel.json": _multimodel_rows,
    "BENCH_faults.json": _faults_rows,
}


def headline_table() -> list[str]:
    rows: list[tuple[str, str, object]] = []
    for name, extract in _HEADLINES.items():
        data = _load(name)
        if data is None:
            continue
        try:
            rows.extend((name, k, v) for k, v in extract(data))
        except (KeyError, TypeError, ValueError):
            rows.append((name, "(unreadable)", None))
    if not rows:
        return []
    lines = ["### Headline numbers", "",
             "| file | metric | value |", "|---|---|---|"]
    lines.extend(f"| `{f}` | {k} | {_fmt(v)} |" for f, k, v in rows)
    return lines


def main() -> int:
    out: list[str] = []
    summary = _load("BENCH_summary.json")
    if isinstance(summary, dict):
        out.extend(section_table(summary))
    headlines = headline_table()
    if headlines:
        if out:
            out.append("")
        out.extend(headlines)
    if not out:
        out = ["_No benchmark result files found._"]
    print("\n".join(out))
    return 0


if __name__ == "__main__":
    sys.exit(main())
