"""Docs link checker: internal anchors + relative paths must resolve.

Scans ``README.md`` and ``docs/*.md`` for markdown links and verifies

  - relative file targets exist (resolved against the linking file);
  - ``#anchor`` fragments (same-file or cross-file) match a real
    heading under GitHub's slugification rules;
  - http(s) targets are *not* fetched (CI must not flake on the
    network) — only counted.

Exit nonzero listing every broken link, so documented paths cannot
rot silently. Run directly or via the CI ``docs`` job:

    python tools/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = ["README.md", *sorted(p.relative_to(REPO).as_posix()
                                  for p in (REPO / "docs").glob("*.md"))]

# [text](target) — ignore images' leading ! (targets checked the same)
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.M)
# fenced code blocks must not contribute links or headings
_FENCE = re.compile(r"```.*?```", re.S)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase; drop everything that is not a
    word character, space, or hyphen; spaces become hyphens."""
    h = heading.strip().lower()
    h = re.sub(r"[`*_]", "", h)               # inline formatting
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def anchors_of(text: str) -> set[str]:
    out: set[str] = set()
    seen: dict[str, int] = {}
    for m in _HEADING.finditer(_FENCE.sub("", text)):
        slug = github_slug(m.group(1))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        out.add(slug if n == 0 else f"{slug}-{n}")
    return out


def check_file(rel: str, cache: dict[str, set[str]]) -> list[str]:
    path = REPO / rel
    text = path.read_text(encoding="utf-8")
    cache.setdefault(rel, anchors_of(text))
    problems = []
    for m in _LINK.finditer(_FENCE.sub("", text)):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("../../"):
            continue   # repo-external (e.g. the Actions badge route)
        frag = None
        if "#" in target:
            target, frag = target.split("#", 1)
        if target:
            dest = (path.parent / target).resolve()
            if not dest.exists():
                problems.append(f"{rel}: broken path link -> {m.group(1)}")
                continue
            try:
                dest_rel = dest.relative_to(REPO).as_posix()
            except ValueError:
                problems.append(f"{rel}: link escapes repo -> {m.group(1)}")
                continue
        else:
            dest, dest_rel = path, rel
        if frag is not None:
            if dest.suffix.lower() not in (".md", ".markdown"):
                continue
            if dest_rel not in cache:
                cache[dest_rel] = anchors_of(
                    dest.read_text(encoding="utf-8"))
            if frag.lower() not in cache[dest_rel]:
                problems.append(
                    f"{rel}: broken anchor -> {m.group(1)} "
                    f"(no heading slug {frag!r} in {dest_rel})")
    return problems


def main() -> int:
    cache: dict[str, set[str]] = {}
    problems = []
    checked = 0
    for rel in DOC_FILES:
        if not (REPO / rel).exists():
            problems.append(f"missing doc file: {rel}")
            continue
        problems += check_file(rel, cache)
        checked += 1
    print(f"[check_docs] checked {checked} file(s): "
          f"{', '.join(DOC_FILES)}")
    if problems:
        for p in problems:
            print(f"[check_docs] {p}", file=sys.stderr)
        return 1
    print("[check_docs] all internal links and anchors resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
