"""End-to-end trigger training: object-condensation loss on synthetic
Belle II events, with async checkpointing and a simulated node failure
mid-run (restore-and-resume).

    PYTHONPATH=src python examples/train_trigger.py --steps 300
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import caloclusternet as ccn
from repro.core.condensation import condensation_loss
from repro.data import Prefetcher
from repro.data.belle2 import Belle2Config, generate
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_warmup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/trigger_ckpt")
    ap.add_argument("--inject-failure-at", type=int, default=150)
    args = ap.parse_args()

    cfg = ccn.CCNConfig(n_hits=32, n_crystals=576)
    gen = Belle2Config(n_crystals=576, grid=(24, 24), n_hits=32,
                       noise_rate=8.0)
    ocfg = AdamWConfig(weight_decay=0.01)
    lr = cosine_warmup(peak_lr=2e-3, warmup_steps=30,
                       total_steps=args.steps)

    params = ccn.init(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params, ocfg)
    mgr = CheckpointManager(args.ckpt_dir, keep=2, async_=True)

    @jax.jit
    def step(params, opt, batch):
        def lf(p):
            out = ccn.apply(p, batch["feats"], batch["mask"], cfg)
            labels = {"object_id": batch["object_id"],
                      "energy": batch["energy"], "cls": batch["cls"]}
            return condensation_loss(out, labels, batch["mask"],
                                     k_max=cfg.k_max)
        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(
            params)
        p2, o2, aux = adamw_update(grads, opt, params,
                                   lr=lr(opt["step"]), cfg=ocfg)
        return p2, o2, {**metrics, **aux}

    def stream(start):
        s = start
        while True:
            yield generate(gen, args.batch, seed=1000 + s)
            s += 1

    s = 0
    injected = False
    losses = []
    pf = Prefetcher(stream(0), depth=2)
    t0 = time.time()
    while s < args.steps:
        if s == args.inject_failure_at and not injected:
            injected = True
            print(f">>> simulated node failure at step {s}: restoring")
            mgr.wait()
            if mgr.latest() is not None:
                restored, s = mgr.restore_latest({"p": params, "o": opt})
                params, opt = restored["p"], restored["o"]
                pf.close()
                pf = Prefetcher(stream(s), depth=2)
                print(f">>> resumed from step {s}")
            continue
        raw = pf.get()
        batch = {k: jnp.asarray(v) for k, v in raw.items()
                 if k != "trigger_truth"}
        params, opt, m = step(params, opt, batch)
        s += 1
        losses.append(float(m["loss"]))
        if s % 25 == 0:
            print(f"step {s:4d} loss {losses[-1]:.4f} "
                  f"(pot {float(m['l_potential']):.3f} "
                  f"beta {float(m['l_beta']):.3f}) "
                  f"{s / (time.time() - t0):.1f} steps/s")
        if s % 50 == 0:
            mgr.save(s, {"p": params, "o": opt})
    mgr.wait()
    pf.close()

    # evaluate trigger quality
    test = generate(gen, 256, seed=9999)
    out = ccn.apply(params, jnp.asarray(test["feats"]),
                    jnp.asarray(test["mask"]), cfg)
    res = ccn.cps(out, jnp.asarray(test["mask"]), cfg)
    trig = np.asarray(res["trigger"])
    truth = test["trigger_truth"] > 0
    eff = (trig & truth).sum() / max(truth.sum(), 1)
    fake = (trig & ~truth).sum() / max((~truth).sum(), 1)
    print(f"final: loss {np.mean(losses[-20:]):.4f} "
          f"(first20 {np.mean(losses[:20]):.4f}); "
          f"trigger eff {eff:.3f}, fake rate {fake:.3f}")
    assert np.mean(losses[-20:]) < np.mean(losses[:20]), \
        "training did not reduce the loss"


if __name__ == "__main__":
    main()
