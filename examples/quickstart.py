"""Quickstart: deploy CaloClusterNet through the paper's design flow and
run trigger inference on synthetic Belle II events.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import caloclusternet as ccn
from repro.core.passes.parallelize import Requirements
from repro.core.pipeline import deploy
from repro.data.belle2 import Belle2Config, generate


def main():
    # 1. the model (upgraded detector: 128 of 8736 sparse inputs)
    cfg = ccn.CCNConfig()
    params = ccn.init(jax.random.PRNGKey(0), cfg)

    # 2. synthetic events from the Belle II ECL generator
    events = generate(Belle2Config(), batch=64, seed=42)
    feeds = {"hits": events["feats"], "mask": events["mask"]}

    # 3. export the dataflow graph and run the deployment flow
    #    (fusion -> partitioning -> mapping -> parallelization -> kernel opt)
    graph = ccn.to_graph(params, cfg)
    print(f"dataflow graph: {len(graph)} operators, "
          f"multicasts before fusion: {len(graph.multicast_ops())}")
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="mixed", n_hits=cfg.n_hits,
                       target_throughput=5e4, max_latency_s=2e-3)
    pipe = deploy(graph, req, calibration_feeds=feeds)
    print(f"deployed: {len(pipe.segments)} pipeline segments "
          f"(paper: 7), P={pipe.par['P_mxu']}/{pipe.par['P_xla']}, "
          f"precision=mixed (bf16 boundary / int8 interior)")

    # 4. trigger inference (params are UNTRAINED here — decisions are
    #    arbitrary; run examples/train_trigger.py for a trained trigger)
    out = pipe(feeds)
    trig = np.asarray(out["cps"]["trigger"])
    truth = events["trigger_truth"] > 0
    print(f"trigger decisions (untrained params): {trig.sum()}/{len(trig)}"
          f" fired (truth: {truth.sum()})")
    nclus = np.asarray(out["cps"]["n_clusters"])
    print(f"clusters/event: mean {nclus.mean():.2f} max {nclus.max()}")

    # 5. the same model as a plain differentiable function (training path)
    ref = ccn.apply(params, feeds["hits"], feeds["mask"], cfg)
    err = np.max(np.abs(np.asarray(out["coords"])
                        - np.asarray(ref["coords"])))
    print(f"deployed-vs-functional max deviation (int8 interior): "
          f"{err:.4f}")


if __name__ == "__main__":
    main()
