"""Train a transformer LM for a few hundred steps on the
synthetic LM stream (shares the exact step/substrate code the pod-scale
cells lower — scan-over-layers, remat, AdamW, checkpointing).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import Prefetcher
from repro.data.lm import lm_stream
from repro.models import transformer as tr
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_warmup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--vocab", type=int, default=8000)
    ap.add_argument("--ckpt-dir", default="/tmp/lm_ckpt")
    args = ap.parse_args()

    # default compact config for single-core CPU demo runs; pass
    # --d-model 768 --layers 8 --vocab 32000 for the ~100M variant
    cfg = tr.TransformerConfig(
        name="lm-demo", n_layers=args.layers, d_model=args.d_model,
        n_heads=max(4, args.d_model // 64), n_kv_heads=4,
        d_ff=int(args.d_model * 2.75) // 16 * 16, vocab=args.vocab,
        rope_theta=1e4, block_q=64, loss_chunk=64,
        compute_dtype=jnp.float32)
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    print(f"model: {n_params / 1e6:.1f}M params")

    ocfg = AdamWConfig()
    lr = cosine_warmup(peak_lr=6e-4, warmup_steps=30,
                       total_steps=args.steps)
    opt = adamw_init(params, ocfg)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    @jax.jit
    def step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(
            tr.loss_fn, has_aux=True)(params, batch, cfg, None)
        p2, o2, aux = adamw_update(grads, opt, params,
                                   lr=lr(opt["step"]), cfg=ocfg)
        return p2, o2, {**metrics, **aux}

    losses = []
    t0 = time.time()
    with Prefetcher(lm_stream(cfg.vocab, args.batch, args.seq, seed=0),
                    depth=2) as pf:
        for s in range(1, args.steps + 1):
            raw = pf.get()
            batch = {"tokens": jnp.asarray(raw["tokens"]),
                     "labels": jnp.asarray(raw["labels"])}
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["ce"]))
            if s % 20 == 0:
                tok_s = s * args.batch * args.seq / (time.time() - t0)
                print(f"step {s:4d} ce {losses[-1]:.4f} "
                      f"({tok_s:,.0f} tok/s)")
            if s % 100 == 0:
                mgr.save(s, {"p": params, "o": opt})
    mgr.wait()
    print(f"ce: {np.mean(losses[:10]):.3f} -> {np.mean(losses[-10:]):.3f}")
    assert np.mean(losses[-10:]) < np.mean(losses[:10])


if __name__ == "__main__":
    main()
