"""Real-time trigger serving demo (the paper's end-to-end demonstrator):
deployment flow -> compiled pipeline -> streaming engine with strict
in-order completion, micro-batching deadline, and an event-display JSON
(the interactive-visualization analogue).

    PYTHONPATH=src python examples/serve_trigger.py
"""
import sys

from repro.launch import serve


def main():
    sys.argv = [sys.argv[0], "--detector", "current", "--design-point",
                "3", "--events", "256", "--train-steps", "200",
                "--event-display", "/tmp/event_display.json"] \
        + sys.argv[1:]
    serve.main()


if __name__ == "__main__":
    main()
