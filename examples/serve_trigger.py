"""Real-time trigger serving demo (the paper's end-to-end demonstrator):
deployment flow -> compiled pipeline -> sharded streaming service with
strict in-order completion across replicas, micro-batching deadline,
the live monitoring endpoint (/snapshot JSON, /events NDJSON, an
HTML/SVG event display on an ephemeral port), and an event-display
JSON written through the shared ``event_display`` helper.

    PYTHONPATH=src python examples/serve_trigger.py
    PYTHONPATH=src python examples/serve_trigger.py --replicas 4

(extra flags are forwarded to ``repro.launch.serve``; see docs/serving.md)
"""
import sys

from repro.launch import serve


def main():
    sys.argv = [sys.argv[0], "--detector", "current", "--design-point",
                "3", "--events", "256", "--train-steps", "200",
                "--replicas", "2", "--monitor-port", "0",
                "--event-display", "/tmp/event_display.json"] \
        + sys.argv[1:]
    serve.main()


if __name__ == "__main__":
    main()
